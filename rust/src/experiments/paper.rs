//! The paper's published evaluation numbers, as data.
//!
//! Tables 2–5 of IJDPS 3(2) 2012 (the paper numbers its result tables
//! 2, 1, 2, 3 — a typesetting accident; we index them 2..5 in n-order
//! 64/128/256/512). Every bench prints these next to the simulated and
//! measured columns so the reproduction is checkable cell by cell.

/// One published cell: wall-clock seconds for (method, n, power).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PaperCell {
    /// The exponent `N` of this column.
    pub power: u64,
    /// Published naive-GPU seconds.
    pub naive_gpu_s: f64,
    /// Published sequential-CPU seconds.
    pub seq_cpu_s: f64,
    /// Published "Our Approach" seconds.
    pub ours_s: f64,
}

impl PaperCell {
    /// "Naïve Speed UP" row: sequential CPU / naive GPU.
    pub fn naive_speedup(&self) -> f64 {
        self.seq_cpu_s / self.naive_gpu_s
    }

    /// "Our Approach vs Naïve GPU" row.
    pub fn ours_vs_naive(&self) -> f64 {
        self.naive_gpu_s / self.ours_s
    }

    /// Our approach vs sequential CPU (Figs 6/8/10/12 tall bars).
    pub fn ours_speedup(&self) -> f64 {
        self.seq_cpu_s / self.ours_s
    }
}

/// One published table: matrix size + its cells.
#[derive(Clone, Debug)]
pub struct PaperTable {
    /// Our table id (2..=5).
    pub id: u8,
    /// Matrix size n (n×n).
    pub n: usize,
    /// The published columns, in power order.
    pub cells: &'static [PaperCell],
}

const T2: &[PaperCell] = &[
    PaperCell { power: 64, naive_gpu_s: 0.05, seq_cpu_s: 0.23, ours_s: 0.01 },
    PaperCell { power: 128, naive_gpu_s: 0.14, seq_cpu_s: 0.68, ours_s: 0.01 },
    PaperCell { power: 256, naive_gpu_s: 0.43, seq_cpu_s: 1.74, ours_s: 0.02 },
    PaperCell { power: 512, naive_gpu_s: 0.99, seq_cpu_s: 4.31, ours_s: 0.02 },
    PaperCell { power: 1024, naive_gpu_s: 2.69, seq_cpu_s: 10.83, ours_s: 0.03 },
];

const T3: &[PaperCell] = &[
    PaperCell { power: 64, naive_gpu_s: 0.10, seq_cpu_s: 1.83, ours_s: 0.02 },
    PaperCell { power: 128, naive_gpu_s: 0.25, seq_cpu_s: 5.72, ours_s: 0.02 },
    PaperCell { power: 256, naive_gpu_s: 0.62, seq_cpu_s: 13.18, ours_s: 0.02 },
    PaperCell { power: 512, naive_gpu_s: 1.38, seq_cpu_s: 27.53, ours_s: 0.02 },
];

const T4: &[PaperCell] = &[
    PaperCell { power: 64, naive_gpu_s: 0.21, seq_cpu_s: 16.0, ours_s: 0.03 },
    PaperCell { power: 128, naive_gpu_s: 0.43, seq_cpu_s: 32.19, ours_s: 0.03 },
    PaperCell { power: 256, naive_gpu_s: 0.87, seq_cpu_s: 64.61, ours_s: 0.04 },
    PaperCell { power: 512, naive_gpu_s: 1.76, seq_cpu_s: 129.38, ours_s: 0.04 },
];

const T5: &[PaperCell] = &[
    PaperCell { power: 64, naive_gpu_s: 0.26, seq_cpu_s: 78.49, ours_s: 0.12 },
    PaperCell { power: 128, naive_gpu_s: 0.43, seq_cpu_s: 157.62, ours_s: 0.13 },
    PaperCell { power: 256, naive_gpu_s: 0.87, seq_cpu_s: 315.74, ours_s: 0.14 },
];

/// All four result tables in n-order.
pub fn paper_tables() -> [PaperTable; 4] {
    [
        PaperTable { id: 2, n: 64, cells: T2 },
        PaperTable { id: 3, n: 128, cells: T3 },
        PaperTable { id: 4, n: 256, cells: T4 },
        PaperTable { id: 5, n: 512, cells: T5 },
    ]
}

/// Look up a table by our id (2..=5).
pub fn paper_table(id: u8) -> Option<PaperTable> {
    paper_tables().into_iter().find(|t| t.id == id)
}

/// The published cell for (n, power), if the paper reports it.
pub fn paper_cell(n: usize, power: u64) -> Option<PaperCell> {
    paper_tables()
        .into_iter()
        .find(|t| t.n == n)
        .and_then(|t| t.cells.iter().copied().find(|c| c.power == power))
}

/// Observations for calibration: every published (n, power, naive_gpu_s).
pub fn naive_gpu_observations() -> Vec<crate::simulator::calibrate::Observation> {
    paper_tables()
        .iter()
        .flat_map(|t| {
            t.cells.iter().map(|c| crate::simulator::calibrate::Observation {
                n: t.n,
                power: c.power,
                seconds: c.naive_gpu_s,
            })
        })
        .collect()
}

/// Observations for session-overhead calibration: every published
/// "Our Approach" cell.
pub fn ours_observations() -> Vec<crate::simulator::calibrate::Observation> {
    paper_tables()
        .iter()
        .flat_map(|t| {
            t.cells.iter().map(|c| crate::simulator::calibrate::Observation {
                n: t.n,
                power: c.power,
                seconds: c.ours_s,
            })
        })
        .collect()
}

/// Observations for CPU calibration: every published sequential-CPU cell.
pub fn seq_cpu_observations() -> Vec<crate::simulator::calibrate::Observation> {
    paper_tables()
        .iter()
        .flat_map(|t| {
            t.cells.iter().map(|c| crate::simulator::calibrate::Observation {
                n: t.n,
                power: c.power,
                seconds: c.seq_cpu_s,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_tables_cover_paper_sizes() {
        let ids: Vec<u8> = paper_tables().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![2, 3, 4, 5]);
        let ns: Vec<usize> = paper_tables().iter().map(|t| t.n).collect();
        assert_eq!(ns, vec![64, 128, 256, 512]);
    }

    #[test]
    fn headline_cells_match_abstract() {
        // "44 fold speedup with the naive GPU Kernel" — Table 4, N=512
        let c = paper_cell(256, 512).unwrap();
        assert!((c.ours_vs_naive() - 44.0).abs() < 0.1, "{}", c.ours_vs_naive());
        // "1000X speedup" — ours vs sequential CPU at n=256/512
        assert!(paper_cell(256, 512).unwrap().ours_speedup() > 1000.0);
        assert!(paper_cell(512, 256).unwrap().ours_speedup() > 1000.0);
    }

    #[test]
    fn published_speedup_rows_reproduce() {
        // Table 2's printed "Naïve Speed UP" row: 4.6, 4.86, 4.05, 4.35, 4.03
        let t = paper_table(2).unwrap();
        let printed = [4.6, 4.86, 4.05, 4.35, 4.03];
        for (c, want) in t.cells.iter().zip(printed) {
            assert!((c.naive_speedup() - want).abs() < 0.05, "{} vs {want}", c.naive_speedup());
        }
        // Table 4's "Our Approach vs Naïve GPU": 7, 14.33, 21.75, 44
        let t = paper_table(4).unwrap();
        let printed = [7.0, 14.33, 21.75, 44.0];
        for (c, want) in t.cells.iter().zip(printed) {
            assert!((c.ours_vs_naive() - want).abs() < 0.05, "{} vs {want}", c.ours_vs_naive());
        }
    }

    #[test]
    fn lookup_misses_are_none() {
        assert!(paper_cell(100, 64).is_none());
        assert!(paper_cell(64, 100).is_none());
        assert!(paper_table(1).is_none());
        assert!(paper_table(6).is_none());
    }

    #[test]
    fn observation_counts() {
        assert_eq!(naive_gpu_observations().len(), 5 + 4 + 4 + 3);
        assert_eq!(seq_cpu_observations().len(), 16);
    }
}
