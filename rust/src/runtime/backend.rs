//! The pluggable execution layer: a [`Backend`] owns device buffers and
//! executes the fixed launch vocabulary the planner emits; the generic
//! [`crate::runtime::Engine`] replays plans on top of it.
//!
//! The paper's contribution is the *coordination* of launches (device
//! residency, fused square-and-multiply), not any one GPU substrate, so
//! the launch vocabulary is the trait boundary:
//!
//! | op         | inputs        | output      | multiplies |
//! |------------|---------------|-------------|------------|
//! | `matmul`   | A, B          | A·B         | 1          |
//! | `square`   | A             | A²          | 1          |
//! | `square{k}`| A             | A^(2^k)     | k          |
//! | `sqmul`    | acc, base     | (acc·base, base²) pair | 2 |
//! | `pack2`    | B             | (B, B) pair | 0          |
//! | `step_sq`  | (acc, base)   | (acc, base²)| 1          |
//! | `step_mul` | (acc, base)   | (acc·base², base²) | 2   |
//! | `unpack0`  | (acc, base)   | acc         | 0          |
//! | `expm{N}`  | A             | A^N         | binary(N)  |
//! | `mma{g}`   | A1..Ag, B1..Bg | sum Ak·Bk  | g          |
//!
//! `mma{g}` is the tile kernel of the multi-device layer
//! ([`crate::pool`]): one launch accumulates a whole block-row×block-column
//! inner product, so a device computes its output tile of a sharded
//! multiply in a single dispatch instead of `g` launches plus host adds.
//!
//! Three implementations ship: [`crate::runtime::CpuBackend`] (pure Rust,
//! runs everywhere — the default), [`crate::runtime::SimBackend`] (the
//! calibratable Tesla C2050 timing model; numerics via the CPU substrate,
//! wall-clock simulated), and, behind the `xla` cargo feature,
//! [`crate::runtime::PjrtBackend`] (AOT HLO artifacts on PJRT).

use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::plan::Plan;

/// Exponents the fused single-launch `expm{N}` op is available for — the
/// same set `make artifacts` AOT-lowers, mirrored by every backend so
/// "fused artifact for N" availability is backend-independent.
pub const FUSED_EXPM_POWERS: [u64; 5] = [64, 128, 256, 512, 1024];

/// Result of splitting a packed `[acc, base]` pair buffer, with the
/// host↔device transfers the split cost on this backend: PJRT must
/// round-trip the 2-tuple through the host (2 D2H + 2 H2D — ablation A2's
/// "bad arm"); the pure-Rust backends split in place for free.
pub struct SplitPair<B> {
    pub first: B,
    pub second: B,
    pub h2d_transfers: usize,
    pub d2h_transfers: usize,
}

/// A device-like execution substrate: opaque buffers plus the launch
/// vocabulary above. Launch/transfer *accounting* lives in the engine —
/// backends only move data and compute.
///
/// Backends may be `!Send` (PJRT objects live on their creating thread);
/// the coordinator gives each worker thread its own backend.
pub trait Backend {
    /// Opaque device buffer handle; clones alias the same device data.
    type Buffer: Clone;

    /// Short machine name (`cpu` / `sim` / `pjrt`) for logs and metrics.
    fn name(&self) -> &'static str;

    /// Human-readable platform summary (for `matexp info`).
    fn platform(&self) -> String;

    /// Compile/cache `op` at size `n`, erroring if this backend cannot
    /// execute it (unknown op, missing artifact). Engines call this
    /// outside timed regions so launches measure steady state.
    fn prepare(&mut self, op: &str, n: usize) -> Result<()>;

    /// Host matrix → device buffer (one H2D transfer).
    fn upload(&mut self, m: &Matrix) -> Result<Self::Buffer>;

    /// Device buffer → host matrix (one D2H transfer). Errors on a packed
    /// pair buffer — unpack first.
    fn download(&mut self, buf: &Self::Buffer, n: usize) -> Result<Matrix>;

    /// One kernel launch of `op` at size `n` over device buffers.
    fn launch(&mut self, op: &str, n: usize, inputs: &[Self::Buffer]) -> Result<Self::Buffer>;

    /// Split a packed pair buffer into its two matrices, reporting what
    /// the split cost in transfers on this backend.
    fn split_pair(&mut self, buf: &Self::Buffer, n: usize) -> Result<SplitPair<Self::Buffer>>;

    /// Simulated seconds accumulated since the last call, for backends
    /// whose wall-clock is modeled rather than measured ([`super::SimBackend`]).
    /// Engines call this when a timed region starts (to reset) and ends
    /// (to use the simulated duration instead of real elapsed time).
    fn take_sim_time(&mut self) -> Option<f64> {
        None
    }

    /// Whether this backend's reported times are modeled rather than
    /// measured. Callers comparing against host-side baselines (the
    /// experiment harness's sequential-CPU arm) must model that baseline
    /// too, or the comparison mixes 2012-simulated and real seconds.
    fn models_time(&self) -> bool {
        false
    }
}

/// Matrix multiplies one launch of `op` performs (the quantity behind the
/// paper's tables). Errors on an op outside the vocabulary.
pub fn op_multiplies(op: &str) -> Result<usize> {
    match op {
        "matmul" | "square" | "step_sq" => Ok(1),
        "sqmul" | "step_mul" => Ok(2),
        "pack2" | "unpack0" => Ok(0),
        _ => {
            if let Some(g) = op.strip_prefix("mma") {
                return g
                    .parse::<usize>()
                    .map_err(|_| MatexpError::Backend(format!("unknown op {op:?}")));
            }
            if let Some(k) = op.strip_prefix("square") {
                return k
                    .parse::<usize>()
                    .map_err(|_| MatexpError::Backend(format!("unknown op {op:?}")));
            }
            if let Some(power) = op.strip_prefix("expm") {
                let power: u64 = power
                    .parse()
                    .map_err(|_| MatexpError::Backend(format!("unknown op {op:?}")))?;
                return Ok(Plan::binary(power.max(1), false).multiplies());
            }
            Err(MatexpError::Backend(format!("unknown op {op:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplies_per_op() {
        assert_eq!(op_multiplies("matmul").unwrap(), 1);
        assert_eq!(op_multiplies("square").unwrap(), 1);
        assert_eq!(op_multiplies("square4").unwrap(), 4);
        assert_eq!(op_multiplies("sqmul").unwrap(), 2);
        assert_eq!(op_multiplies("step_mul").unwrap(), 2);
        assert_eq!(op_multiplies("step_sq").unwrap(), 1);
        assert_eq!(op_multiplies("pack2").unwrap(), 0);
        assert_eq!(op_multiplies("unpack0").unwrap(), 0);
        // expm{N} = the binary plan's multiply count
        assert_eq!(op_multiplies("expm64").unwrap(), 6);
        assert_eq!(op_multiplies("expm100").unwrap(), 8);
        // mma{g} = g tile multiplies in one launch
        assert_eq!(op_multiplies("mma1").unwrap(), 1);
        assert_eq!(op_multiplies("mma4").unwrap(), 4);
        assert!(op_multiplies("conv2d").is_err());
        assert!(op_multiplies("squareX").is_err());
        assert!(op_multiplies("mmaX").is_err());
    }
}
