//! The pluggable execution layer: a [`Backend`] owns device buffers and
//! executes the typed launch vocabulary ([`KernelOp`]) the planner emits;
//! the generic [`crate::runtime::Engine`] replays plans on top of it.
//!
//! The paper's contribution is the *coordination* of launches (device
//! residency, fused square-and-multiply), not any one GPU substrate, so
//! the launch vocabulary is the trait boundary — see [`KernelOp`] for the
//! full op table. `Mma(g)` is the tile kernel of the multi-device layer
//! ([`crate::pool`]): one launch accumulates a whole block-row×block-column
//! inner product, so a device computes its output tile of a sharded
//! multiply in a single dispatch instead of `g` launches plus host adds.
//!
//! Data-path contract: `upload` takes **ownership** (a backend may adopt
//! the allocation without copying), `launch` may write into a recycled
//! buffer from its [`super::arena::BufferArena`], and `split_pair`
//! **consumes** its pair. The [`ResidencyStats`] a backend reports through
//! [`Backend::take_residency`] quantify what the data path actually cost:
//! host-edge bytes copied, recycled-buffer hits, and the resident
//! high-water mark.
//!
//! Three implementations ship: [`crate::runtime::CpuBackend`] (pure Rust,
//! runs everywhere — the default), [`crate::runtime::SimBackend`] (the
//! calibratable Tesla C2050 timing model; numerics via the CPU substrate,
//! wall-clock simulated), and, behind the `xla` cargo feature,
//! [`crate::runtime::PjrtBackend`] (AOT HLO artifacts on PJRT).

use crate::error::Result;
use crate::linalg::matrix::Matrix;
use crate::runtime::op::KernelOp;

/// Exponents the fused single-launch [`KernelOp::Expm`] op is available
/// for — the same set `make artifacts` AOT-lowers, mirrored by every
/// backend so "fused artifact for N" availability is backend-independent.
pub const FUSED_EXPM_POWERS: [u64; 5] = [64, 128, 256, 512, 1024];

/// Result of splitting a packed `[acc, base]` pair buffer, with the
/// host↔device transfers the split cost on this backend: PJRT must
/// round-trip the 2-tuple through the host (2 D2H + 2 H2D — ablation A2's
/// "bad arm"); the pure-Rust backends split in place for free.
pub struct SplitPair<B> {
    /// The pair's first half (`acc`).
    pub first: B,
    /// The pair's second half (`base`).
    pub second: B,
    /// Host→device transfers the split cost on this backend.
    pub h2d_transfers: usize,
    /// Device→host transfers the split cost on this backend.
    pub d2h_transfers: usize,
}

/// What the data path cost since the last [`Backend::take_residency`]:
/// the counters behind `ExecStats.{bytes_copied, buffers_recycled,
/// peak_resident_bytes}`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Bytes that crossed the host↔device edge (uploads, downloads, and
    /// any forced internal round-trips such as a modeled pair split).
    pub bytes_copied: u64,
    /// Output allocations served from the backend's buffer arena instead
    /// of a fresh allocation.
    pub buffers_recycled: u64,
    /// High-water mark of live device-buffer bytes.
    pub peak_resident_bytes: u64,
}

/// A device-like execution substrate: opaque buffers plus the typed
/// launch vocabulary. Launch/transfer *accounting* lives in the engine —
/// backends only move data and compute (and report residency counters).
///
/// Backends may be `!Send` (PJRT objects live on their creating thread);
/// the coordinator gives each worker thread its own backend.
pub trait Backend {
    /// Opaque device buffer handle; clones alias the same device data.
    type Buffer: Clone;

    /// Short machine name (`cpu` / `sim` / `pjrt`) for logs and metrics.
    fn name(&self) -> &'static str;

    /// Human-readable platform summary (for `matexp info`).
    fn platform(&self) -> String;

    /// Compile/cache `op` at size `n`. Engines call this outside timed
    /// regions so launches measure steady state. An op this backend (or
    /// artifact set) genuinely does not ship is
    /// [`crate::error::MatexpError::UnsupportedOp`]; anything else is a
    /// real failure callers must not swallow.
    fn prepare(&mut self, op: KernelOp, n: usize) -> Result<()>;

    /// Host matrix → device buffer (one H2D transfer). Takes ownership so
    /// host-resident backends adopt the allocation without copying.
    fn upload(&mut self, m: Matrix) -> Result<Self::Buffer>;

    /// Device buffer → host matrix (one D2H transfer). Errors on a packed
    /// pair buffer — unpack first.
    fn download(&mut self, buf: &Self::Buffer, n: usize) -> Result<Matrix>;

    /// One kernel launch of `op` at size `n` over device buffers.
    fn launch(&mut self, op: KernelOp, n: usize, inputs: &[Self::Buffer]) -> Result<Self::Buffer>;

    /// Split a packed pair buffer (consumed) into its two matrices,
    /// reporting what the split cost in transfers on this backend.
    fn split_pair(&mut self, buf: Self::Buffer, n: usize) -> Result<SplitPair<Self::Buffer>>;

    /// Simulated seconds accumulated since the last call, for backends
    /// whose wall-clock is modeled rather than measured ([`super::SimBackend`]).
    /// Engines call this when a timed region starts (to reset) and ends
    /// (to use the simulated duration instead of real elapsed time).
    fn take_sim_time(&mut self) -> Option<f64> {
        None
    }

    /// Whether this backend's reported times are modeled rather than
    /// measured. Callers comparing against host-side baselines (the
    /// experiment harness's sequential-CPU arm) must model that baseline
    /// too, or the comparison mixes 2012-simulated and real seconds.
    fn models_time(&self) -> bool {
        false
    }

    /// Residency counters accumulated since the last call (engines reset
    /// at the start of a timed region and read at its end). Backends
    /// without a pooled buffer layer report zeros.
    fn take_residency(&mut self) -> ResidencyStats {
        ResidencyStats::default()
    }
}

