//! Artifact manifest: discovery and lookup of the AOT-compiled executables.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! `*.hlo.txt` it lowered; this module is the rust-side reader and index.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{MatexpError, Result};
use crate::runtime::Variant;
use crate::util::json::Json;

/// Manifest schema version this build understands.
pub const SUPPORTED_MANIFEST_VERSION: u64 = 2;

/// One artifact as recorded by aot.py.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Unique manifest name (e.g. `matmul_n256_f32_xla`).
    pub name: String,
    /// Canonical op name ([`crate::runtime::KernelOp::name`] vocabulary).
    pub op: String,
    /// Matrix side length the artifact was lowered for.
    pub n: usize,
    /// Element dtype (`f32`).
    pub dtype: String,
    /// Kernel variant (`xla` / `pallas`).
    pub variant: String,
    /// Number of input buffers the executable takes.
    pub num_inputs: usize,
    /// Number of output buffers it produces.
    pub num_outputs: usize,
    /// HLO text filename relative to the artifact directory.
    pub file: String,
    /// Tile block sizes, for tiled matmul entries.
    pub blocks: Option<Vec<usize>>,
    /// Tile label, for tiled entries (`None` = the untiled default).
    pub tile: Option<String>,
    /// Compiler-estimated VMEM footprint, bytes.
    pub vmem_bytes: Option<u64>,
    /// Compiler-estimated MXU utilization (0..1).
    pub mxu_utilization: Option<f64>,
    /// SHA-256 of the HLO text (integrity checks).
    pub sha256: String,
    /// HLO text length in characters (size diagnostics).
    pub hlo_chars: u64,
}

fn field<'a>(v: &'a Json, name: &str) -> Result<&'a Json> {
    v.get(name).ok_or_else(|| {
        MatexpError::Artifact(format!("manifest entry missing field {name:?}"))
    })
}

fn str_field(v: &Json, name: &str) -> Result<String> {
    field(v, name)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| MatexpError::Artifact(format!("manifest field {name:?} not a string")))
}

fn usize_field(v: &Json, name: &str) -> Result<usize> {
    field(v, name)?
        .as_usize()
        .ok_or_else(|| MatexpError::Artifact(format!("manifest field {name:?} not an integer")))
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<ArtifactEntry> {
        Ok(ArtifactEntry {
            name: str_field(v, "name")?,
            op: str_field(v, "op")?,
            n: usize_field(v, "n")?,
            dtype: str_field(v, "dtype")?,
            variant: str_field(v, "variant")?,
            num_inputs: usize_field(v, "num_inputs")?,
            num_outputs: usize_field(v, "num_outputs")?,
            file: str_field(v, "file")?,
            blocks: v.get("blocks").and_then(Json::as_usize_vec),
            tile: v.get("tile").and_then(|t| t.as_str().map(str::to_string)),
            vmem_bytes: v.get("vmem_bytes").and_then(Json::as_u64),
            mxu_utilization: v.get("mxu_utilization").and_then(Json::as_f64),
            sha256: v
                .get("sha256")
                .and_then(|s| s.as_str().map(str::to_string))
                .unwrap_or_default(),
            hlo_chars: v.get("hlo_chars").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

/// Indexed view over the artifact directory.
#[derive(Debug)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    entries: Vec<ArtifactEntry>,
    /// (op, n, dtype, variant) → index of the *untiled* (default) entry.
    by_key: HashMap<(String, usize, String, String), usize>,
}

impl ArtifactRegistry {
    /// Read and index `dir/manifest.json`.
    pub fn discover(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            MatexpError::Artifact(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let doc = Json::parse(&text)?;
        let version = doc.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version != SUPPORTED_MANIFEST_VERSION {
            return Err(MatexpError::Artifact(format!(
                "manifest version {version} unsupported (want {SUPPORTED_MANIFEST_VERSION}); re-run `make artifacts`"
            )));
        }
        let entries: Vec<ArtifactEntry> = doc
            .get("entries")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<_>>()?;
        let mut by_key = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            if e.tile.is_none() {
                by_key.insert(
                    (e.op.clone(), e.n, e.dtype.clone(), e.variant.clone()),
                    i,
                );
            }
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), entries, by_key })
    }

    /// The artifact directory this registry indexed.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Every manifest entry, in manifest order.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Default (untiled) artifact for `(op, n, f32, variant)`.
    pub fn find(&self, op: &str, n: usize, variant: Variant) -> Result<&ArtifactEntry> {
        self.find_dtype(op, n, "f32", variant)
    }

    /// Default (untiled) artifact for `(op, n, dtype, variant)`.
    pub fn find_dtype(
        &self,
        op: &str,
        n: usize,
        dtype: &str,
        variant: Variant,
    ) -> Result<&ArtifactEntry> {
        self.by_key
            .get(&(op.to_string(), n, dtype.to_string(), variant.as_str().to_string()))
            .map(|&i| &self.entries[i])
            .ok_or_else(|| {
                MatexpError::Artifact(format!(
                    "no artifact for op={op} n={n} dtype={dtype} variant={variant}"
                ))
            })
    }

    /// All tile-sweep entries for `(op, n)` (ablation A1).
    pub fn tiles(&self, op: &str, n: usize) -> Vec<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.n == n && e.tile.is_some())
            .collect()
    }

    /// Matrix sizes with a complete core op set for `variant`.
    pub fn sizes(&self, variant: Variant) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.op == "matmul" && e.variant == variant.as_str() && e.dtype == "f32" && e.tile.is_none())
            .map(|e| e.n)
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }

    /// Powers with a fused whole-exponentiation artifact at size `n`.
    pub fn fused_expm_powers(&self, n: usize) -> Vec<u64> {
        let mut powers: Vec<u64> = self
            .entries
            .iter()
            .filter(|e| e.n == n && e.op.starts_with("expm"))
            .filter_map(|e| e.op[4..].parse().ok())
            .collect();
        powers.sort_unstable();
        powers
    }

    /// Absolute path of an entry's HLO text.
    pub fn path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    const SAMPLE: &str = r#"{
      "version": 2,
      "entries": [
        {"name": "matmul_n8_f32_xla", "op": "matmul", "n": 8, "dtype": "f32",
         "variant": "xla", "num_inputs": 2, "num_outputs": 1,
         "file": "matmul_n8_f32_xla.hlo.txt"},
        {"name": "matmul_n8_f32_pallas_t4", "op": "matmul", "n": 8, "dtype": "f32",
         "variant": "pallas", "num_inputs": 2, "num_outputs": 1,
         "file": "matmul_n8_f32_pallas_t4.hlo.txt", "tile": "t4", "blocks": [4,4,4]},
        {"name": "expm64_n8_f32_xla", "op": "expm64", "n": 8, "dtype": "f32",
         "variant": "xla", "num_inputs": 1, "num_outputs": 1,
         "file": "expm64_n8_f32_xla.hlo.txt"}
      ]
    }"#;

    #[test]
    fn discover_and_find() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), SAMPLE);
        let reg = ArtifactRegistry::discover(dir.path()).unwrap();
        assert_eq!(reg.entries().len(), 3);
        let e = reg.find("matmul", 8, Variant::Xla).unwrap();
        assert_eq!(e.file, "matmul_n8_f32_xla.hlo.txt");
        assert!(reg.find("matmul", 16, Variant::Xla).is_err());
        // tiled entries are not returned by `find`
        assert!(reg.find("matmul", 8, Variant::Pallas).is_err());
        assert_eq!(reg.tiles("matmul", 8).len(), 1);
        assert_eq!(reg.tiles("matmul", 8)[0].blocks, Some(vec![4, 4, 4]));
        assert_eq!(reg.fused_expm_powers(8), vec![64]);
        assert_eq!(reg.sizes(Variant::Xla), vec![8]);
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = TempDir::new().unwrap();
        let err = ArtifactRegistry::discover(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn wrong_version_rejected() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), r#"{"version": 99, "entries": []}"#);
        assert!(ArtifactRegistry::discover(dir.path()).is_err());
    }

    #[test]
    fn malformed_entry_rejected() {
        let dir = TempDir::new().unwrap();
        write_manifest(
            dir.path(),
            r#"{"version": 2, "entries": [{"name": "x", "op": "matmul"}]}"#,
        );
        let err = ArtifactRegistry::discover(dir.path()).unwrap_err().to_string();
        assert!(err.contains("missing field"), "{err}");
    }

    #[test]
    fn shipped_manifest_loads_if_present() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let reg = ArtifactRegistry::discover(&dir).unwrap();
        // paper sizes present in both variants
        for n in [64usize, 128, 256, 512] {
            for op in ["matmul", "square", "sqmul", "square2", "square4"] {
                reg.find(op, n, Variant::Xla).unwrap();
                reg.find(op, n, Variant::Pallas).unwrap();
            }
        }
        assert!(!reg.tiles("matmul", 256).is_empty());
        assert_eq!(reg.fused_expm_powers(64), vec![64, 128, 256, 512, 1024]);
    }
}
