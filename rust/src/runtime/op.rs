//! [`KernelOp`] — the typed kernel IR: every launchable operation as one
//! enum variant, with its arity and multiply count as methods.
//!
//! This replaces the stringly-typed launch vocabulary (`"matmul"`,
//! `"sqmul"`, `"mma{g}"`, …) that used to be re-parsed independently in
//! every backend, the engine warmup lists and the pool's fused-tile
//! launches. Strings survive only at the **artifact/wire edge**:
//! [`KernelOp::name`] renders the canonical artifact name and
//! [`KernelOp::parse`] reads one back — nothing else in the launch path
//! matches on `&str` (a test greps the launch-path sources to keep it
//! that way).
//!
//! | op                  | inputs         | output                 | multiplies |
//! |---------------------|----------------|------------------------|------------|
//! | [`Matmul`]          | A, B           | A·B                    | 1          |
//! | [`Square`]          | A              | A²                     | 1          |
//! | [`SquareChain`]`(k)`| A              | A^(2^k)                | k          |
//! | [`SqMul`]           | acc, base      | (acc·base, base²) pair | 2          |
//! | [`Pack2`]           | B              | (B, B) pair            | 0          |
//! | [`StepSq`]          | (acc, base)    | (acc, base²) pair      | 1          |
//! | [`StepMul`]         | (acc, base)    | (acc·base², base²) pair| 2          |
//! | [`Unpack0`]         | (acc, base)    | acc                    | 0          |
//! | [`Mma`]`(g)`        | A1..Ag, B1..Bg | Σ Ak·Bk                | g          |
//! | [`Expm`]`(N)`       | A              | A^N                    | binary(N)  |
//!
//! [`Matmul`]: KernelOp::Matmul
//! [`Square`]: KernelOp::Square
//! [`SquareChain`]: KernelOp::SquareChain
//! [`SqMul`]: KernelOp::SqMul
//! [`Pack2`]: KernelOp::Pack2
//! [`StepSq`]: KernelOp::StepSq
//! [`StepMul`]: KernelOp::StepMul
//! [`Unpack0`]: KernelOp::Unpack0
//! [`Mma`]: KernelOp::Mma
//! [`Expm`]: KernelOp::Expm

use crate::error::{MatexpError, Result};
use crate::plan::Plan;

/// One kernel in the launch vocabulary. `Copy` + `Eq` + `Hash` so ops key
/// executable caches and appear in plans/jobs as plain data.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelOp {
    /// `A·B` — one multiply, two inputs.
    Matmul,
    /// `A²` — one multiply, one input.
    Square,
    /// `A^(2^k)` in one launch (`k ≥ 2`; `k = 1` is [`KernelOp::Square`]).
    SquareChain(u32),
    /// Fused binary-exponentiation step: `(acc·base, base²)` as one packed
    /// pair output.
    SqMul,
    /// Pack a matrix into an `[acc, base]` pair buffer (`acc = base = B`).
    Pack2,
    /// Packed step: `(acc, base²)`.
    StepSq,
    /// Packed step: `(acc·base², base²)`.
    StepMul,
    /// Extract `acc` from a packed pair.
    Unpack0,
    /// Fused tile multiply-accumulate: `Σ_{k<g} Ak·Bk` in one launch
    /// (`g ≥ 1`; the device pool's sharded-multiply kernel).
    Mma(u32),
    /// Whole `A^N` as a single fused launch (AOT artifact; availability
    /// mirrors [`super::FUSED_EXPM_POWERS`]).
    Expm(u64),
}

impl KernelOp {
    /// Matrix multiplies one launch of this op performs (the quantity
    /// behind the paper's tables).
    pub fn multiplies(self) -> usize {
        match self {
            KernelOp::Matmul | KernelOp::Square | KernelOp::StepSq => 1,
            KernelOp::SqMul | KernelOp::StepMul => 2,
            KernelOp::Pack2 | KernelOp::Unpack0 => 0,
            KernelOp::SquareChain(k) => k as usize,
            KernelOp::Mma(g) => g as usize,
            KernelOp::Expm(power) => Plan::binary(power.max(1), false).multiplies(),
        }
    }

    /// Number of input buffers one launch takes.
    pub fn arity(self) -> usize {
        match self {
            KernelOp::Matmul | KernelOp::SqMul => 2,
            KernelOp::Square
            | KernelOp::SquareChain(_)
            | KernelOp::Pack2
            | KernelOp::StepSq
            | KernelOp::StepMul
            | KernelOp::Unpack0
            | KernelOp::Expm(_) => 1,
            KernelOp::Mma(g) => 2 * g as usize,
        }
    }

    /// Reject parameterized variants outside their domain (`square{k}`
    /// needs `k ≥ 2`, `mma{g}` needs `g ≥ 1`). Backends call this in
    /// `prepare` so a hand-constructed degenerate op fails early.
    pub fn validate(self) -> Result<()> {
        match self {
            KernelOp::SquareChain(k) if k < 2 => Err(MatexpError::Backend(format!(
                "square-chain length must be >= 2, got {k} (use Square for k=1)"
            ))),
            KernelOp::Mma(0) => {
                Err(MatexpError::Backend("mma width must be >= 1".into()))
            }
            KernelOp::Expm(0) => {
                Err(MatexpError::Backend("fused exponent must be >= 1".into()))
            }
            _ => Ok(()),
        }
    }

    /// Canonical artifact/wire name — the ONLY place op names are
    /// rendered. Matches the AOT manifest vocabulary.
    pub fn name(self) -> String {
        match self {
            KernelOp::Matmul => "matmul".into(),
            KernelOp::Square => "square".into(),
            KernelOp::SquareChain(k) => format!("square{k}"),
            KernelOp::SqMul => "sqmul".into(),
            KernelOp::Pack2 => "pack2".into(),
            KernelOp::StepSq => "step_sq".into(),
            KernelOp::StepMul => "step_mul".into(),
            KernelOp::Unpack0 => "unpack0".into(),
            KernelOp::Mma(g) => format!("mma{g}"),
            KernelOp::Expm(power) => format!("expm{power}"),
        }
    }

    /// Parse a canonical name back into the typed op — the ONLY place op
    /// names are matched (artifact manifests, wire payloads).
    pub fn parse(s: &str) -> Result<KernelOp> {
        let unknown = || MatexpError::Backend(format!("unknown op {s:?}"));
        let op = match s {
            "matmul" => KernelOp::Matmul,
            "square" => KernelOp::Square,
            "sqmul" => KernelOp::SqMul,
            "pack2" => KernelOp::Pack2,
            "step_sq" => KernelOp::StepSq,
            "step_mul" => KernelOp::StepMul,
            "unpack0" => KernelOp::Unpack0,
            _ => {
                if let Some(rest) = s.strip_prefix("square") {
                    KernelOp::SquareChain(rest.parse::<u32>().map_err(|_| unknown())?)
                } else if let Some(rest) = s.strip_prefix("mma") {
                    KernelOp::Mma(rest.parse::<u32>().map_err(|_| unknown())?)
                } else if let Some(rest) = s.strip_prefix("expm") {
                    KernelOp::Expm(rest.parse::<u64>().map_err(|_| unknown())?)
                } else {
                    return Err(unknown());
                }
            }
        };
        op.validate()?;
        Ok(op)
    }
}

impl std::fmt::Display for KernelOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplies_per_op() {
        assert_eq!(KernelOp::Matmul.multiplies(), 1);
        assert_eq!(KernelOp::Square.multiplies(), 1);
        assert_eq!(KernelOp::SquareChain(4).multiplies(), 4);
        assert_eq!(KernelOp::SqMul.multiplies(), 2);
        assert_eq!(KernelOp::StepMul.multiplies(), 2);
        assert_eq!(KernelOp::StepSq.multiplies(), 1);
        assert_eq!(KernelOp::Pack2.multiplies(), 0);
        assert_eq!(KernelOp::Unpack0.multiplies(), 0);
        // expm{N} = the binary plan's multiply count
        assert_eq!(KernelOp::Expm(64).multiplies(), 6);
        assert_eq!(KernelOp::Expm(100).multiplies(), 8);
        // mma{g} = g tile multiplies in one launch
        assert_eq!(KernelOp::Mma(1).multiplies(), 1);
        assert_eq!(KernelOp::Mma(4).multiplies(), 4);
    }

    #[test]
    fn arity_per_op() {
        assert_eq!(KernelOp::Matmul.arity(), 2);
        assert_eq!(KernelOp::SqMul.arity(), 2);
        assert_eq!(KernelOp::Square.arity(), 1);
        assert_eq!(KernelOp::SquareChain(3).arity(), 1);
        assert_eq!(KernelOp::Pack2.arity(), 1);
        assert_eq!(KernelOp::StepSq.arity(), 1);
        assert_eq!(KernelOp::StepMul.arity(), 1);
        assert_eq!(KernelOp::Unpack0.arity(), 1);
        assert_eq!(KernelOp::Expm(64).arity(), 1);
        assert_eq!(KernelOp::Mma(3).arity(), 6);
    }

    #[test]
    fn name_parse_roundtrip() {
        let ops = [
            KernelOp::Matmul,
            KernelOp::Square,
            KernelOp::SquareChain(2),
            KernelOp::SquareChain(4),
            KernelOp::SqMul,
            KernelOp::Pack2,
            KernelOp::StepSq,
            KernelOp::StepMul,
            KernelOp::Unpack0,
            KernelOp::Mma(1),
            KernelOp::Mma(7),
            KernelOp::Expm(64),
            KernelOp::Expm(1024),
        ];
        for op in ops {
            assert_eq!(KernelOp::parse(&op.name()).unwrap(), op, "{op}");
        }
    }

    #[test]
    fn parse_rejects_garbage_and_degenerates() {
        for bad in ["conv2d", "squareX", "mmaX", "expmX", "", "square1", "square0", "mma0", "expm0"] {
            assert!(KernelOp::parse(bad).is_err(), "{bad:?}");
        }
        assert!(KernelOp::SquareChain(1).validate().is_err());
        assert!(KernelOp::Mma(0).validate().is_err());
        assert!(KernelOp::Matmul.validate().is_ok());
    }
}
