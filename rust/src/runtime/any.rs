//! [`AnyBackend`] — runtime backend selection.
//!
//! The engine is generic over [`Backend`] (static dispatch, no boxing on
//! the hot path); the coordinator and CLI pick the backend from config at
//! runtime, so they run on this enum, which dispatches each trait call to
//! the selected implementation.

use crate::config::MatexpConfig;
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::runtime::backend::{Backend, ResidencyStats, SplitPair};
use crate::runtime::cpu::{CpuBackend, CpuBuffer};
use crate::runtime::op::KernelOp;
use crate::runtime::sim::SimBackend;
use crate::runtime::BackendKind;

#[cfg(feature = "xla")]
use crate::runtime::artifacts::ArtifactRegistry;
#[cfg(feature = "xla")]
use crate::runtime::pjrt::PjrtBackend;

/// One of the shipped backends, chosen at runtime.
pub enum AnyBackend {
    /// Pure-Rust CPU execution (the default).
    Cpu(CpuBackend),
    /// The calibrated Tesla C2050 timing model.
    Sim(SimBackend),
    /// AOT artifacts on PJRT (cargo feature `xla`).
    #[cfg(feature = "xla")]
    Pjrt(PjrtBackend),
}

/// Buffer handle for [`AnyBackend`].
#[derive(Clone)]
pub enum AnyBuffer {
    /// CPU and simulator backends share the host buffer representation.
    Host(CpuBuffer),
    /// A device-resident PJRT buffer.
    #[cfg(feature = "xla")]
    Pjrt(std::rc::Rc<xla::PjRtBuffer>),
}

impl AnyBuffer {
    fn host(&self) -> Result<&CpuBuffer> {
        // without the xla feature the Host arm is exhaustive
        #[allow(unreachable_patterns, clippy::match_single_binding)]
        match self {
            AnyBuffer::Host(b) => Ok(b),
            _ => Err(MatexpError::Backend("buffer belongs to a different backend".into())),
        }
    }

    fn into_host(self) -> Result<CpuBuffer> {
        #[allow(unreachable_patterns, clippy::match_single_binding)]
        match self {
            AnyBuffer::Host(b) => Ok(b),
            _ => Err(MatexpError::Backend("buffer belongs to a different backend".into())),
        }
    }

    #[cfg(feature = "xla")]
    fn pjrt(&self) -> Result<&std::rc::Rc<xla::PjRtBuffer>> {
        match self {
            AnyBuffer::Pjrt(b) => Ok(b),
            _ => Err(MatexpError::Backend("buffer belongs to a different backend".into())),
        }
    }

    #[cfg(feature = "xla")]
    fn into_pjrt(self) -> Result<std::rc::Rc<xla::PjRtBuffer>> {
        match self {
            AnyBuffer::Pjrt(b) => Ok(b),
            _ => Err(MatexpError::Backend("buffer belongs to a different backend".into())),
        }
    }
}

impl AnyBackend {
    /// Build the backend the config asks for. `pjrt` requires the `xla`
    /// cargo feature AND a discovered artifact directory.
    pub fn from_config(cfg: &MatexpConfig) -> Result<AnyBackend> {
        match cfg.backend {
            BackendKind::Cpu => Ok(AnyBackend::Cpu(CpuBackend::new(cfg.cpu_algo))),
            BackendKind::Sim => {
                // the paper-calibrated C2050 model, so sim-backed stats
                // line up with the experiment harness's simulated columns
                let (model, _) = crate::experiments::tables::calibrated_models();
                Ok(AnyBackend::Sim(SimBackend::new(model)))
            }
            BackendKind::Pjrt => pjrt_from_config(cfg),
            // the pool is a layer above single backends: it owns several
            // AnyBackend instances on worker threads (crate::pool)
            BackendKind::Pool => Err(MatexpError::Config(
                "backend \"pool\" is multi-device; drive it through \
                 pool::PoolEngine (the coordinator and CLI do)"
                    .into(),
            )),
        }
    }

    /// Which backend this instance is.
    pub fn kind(&self) -> BackendKind {
        match self {
            AnyBackend::Cpu(_) => BackendKind::Cpu,
            AnyBackend::Sim(_) => BackendKind::Sim,
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(_) => BackendKind::Pjrt,
        }
    }
}

#[cfg(feature = "xla")]
fn pjrt_from_config(cfg: &MatexpConfig) -> Result<AnyBackend> {
    let registry = ArtifactRegistry::discover(&cfg.artifacts_dir)?;
    Ok(AnyBackend::Pjrt(PjrtBackend::new(&registry, cfg.variant)?))
}

#[cfg(not(feature = "xla"))]
fn pjrt_from_config(_cfg: &MatexpConfig) -> Result<AnyBackend> {
    Err(MatexpError::Config(
        "backend \"pjrt\" needs this crate built with `--features xla`".into(),
    ))
}

fn host_inputs(inputs: &[AnyBuffer]) -> Result<Vec<CpuBuffer>> {
    inputs.iter().map(|b| b.host().map(Clone::clone)).collect()
}

#[cfg(feature = "xla")]
fn pjrt_inputs(inputs: &[AnyBuffer]) -> Result<Vec<std::rc::Rc<xla::PjRtBuffer>>> {
    inputs.iter().map(|b| b.pjrt().map(Clone::clone)).collect()
}

impl Backend for AnyBackend {
    type Buffer = AnyBuffer;

    fn name(&self) -> &'static str {
        match self {
            AnyBackend::Cpu(b) => b.name(),
            AnyBackend::Sim(b) => b.name(),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.name(),
        }
    }

    fn platform(&self) -> String {
        match self {
            AnyBackend::Cpu(b) => b.platform(),
            AnyBackend::Sim(b) => b.platform(),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.platform(),
        }
    }

    fn prepare(&mut self, op: KernelOp, n: usize) -> Result<()> {
        match self {
            AnyBackend::Cpu(b) => b.prepare(op, n),
            AnyBackend::Sim(b) => b.prepare(op, n),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.prepare(op, n),
        }
    }

    fn upload(&mut self, m: Matrix) -> Result<AnyBuffer> {
        match self {
            AnyBackend::Cpu(b) => Ok(AnyBuffer::Host(b.upload(m)?)),
            AnyBackend::Sim(b) => Ok(AnyBuffer::Host(b.upload(m)?)),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => Ok(AnyBuffer::Pjrt(b.upload(m)?)),
        }
    }

    fn download(&mut self, buf: &AnyBuffer, n: usize) -> Result<Matrix> {
        match self {
            AnyBackend::Cpu(b) => b.download(buf.host()?, n),
            AnyBackend::Sim(b) => b.download(buf.host()?, n),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.download(buf.pjrt()?, n),
        }
    }

    fn launch(&mut self, op: KernelOp, n: usize, inputs: &[AnyBuffer]) -> Result<AnyBuffer> {
        match self {
            AnyBackend::Cpu(b) => Ok(AnyBuffer::Host(b.launch(op, n, &host_inputs(inputs)?)?)),
            AnyBackend::Sim(b) => Ok(AnyBuffer::Host(b.launch(op, n, &host_inputs(inputs)?)?)),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => Ok(AnyBuffer::Pjrt(b.launch(op, n, &pjrt_inputs(inputs)?)?)),
        }
    }

    fn split_pair(&mut self, buf: AnyBuffer, n: usize) -> Result<SplitPair<AnyBuffer>> {
        fn wrap<B, F: Fn(B) -> AnyBuffer>(s: SplitPair<B>, f: F) -> SplitPair<AnyBuffer> {
            SplitPair {
                first: f(s.first),
                second: f(s.second),
                h2d_transfers: s.h2d_transfers,
                d2h_transfers: s.d2h_transfers,
            }
        }
        match self {
            AnyBackend::Cpu(b) => Ok(wrap(b.split_pair(buf.into_host()?, n)?, AnyBuffer::Host)),
            AnyBackend::Sim(b) => Ok(wrap(b.split_pair(buf.into_host()?, n)?, AnyBuffer::Host)),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => Ok(wrap(b.split_pair(buf.into_pjrt()?, n)?, AnyBuffer::Pjrt)),
        }
    }

    fn take_sim_time(&mut self) -> Option<f64> {
        match self {
            AnyBackend::Cpu(b) => b.take_sim_time(),
            AnyBackend::Sim(b) => b.take_sim_time(),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.take_sim_time(),
        }
    }

    fn models_time(&self) -> bool {
        match self {
            AnyBackend::Cpu(b) => b.models_time(),
            AnyBackend::Sim(b) => b.models_time(),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.models_time(),
        }
    }

    fn take_residency(&mut self) -> ResidencyStats {
        match self {
            AnyBackend::Cpu(b) => b.take_residency(),
            AnyBackend::Sim(b) => b.take_residency(),
            #[cfg(feature = "xla")]
            AnyBackend::Pjrt(b) => b.take_residency(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_builds_the_selected_backend() {
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Cpu;
        assert_eq!(AnyBackend::from_config(&cfg).unwrap().kind(), BackendKind::Cpu);
        cfg.backend = BackendKind::Sim;
        let sim = AnyBackend::from_config(&cfg).unwrap();
        assert_eq!(sim.kind(), BackendKind::Sim);
        assert!(sim.platform().contains("C2050"), "{}", sim.platform());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn pjrt_without_feature_is_clean_config_error() {
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Pjrt;
        let err = AnyBackend::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }

    #[test]
    fn pool_backend_is_not_a_single_backend() {
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Pool;
        let err = AnyBackend::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("pool"), "{err}");
    }

    #[test]
    fn dispatch_roundtrip_through_cpu() {
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Cpu;
        let mut b = AnyBackend::from_config(&cfg).unwrap();
        let m = Matrix::random(8, 5);
        let buf = b.upload(m.clone()).unwrap();
        let sq = b.launch(KernelOp::Square, 8, &[buf]).unwrap();
        let want = crate::linalg::naive::matmul_naive(&m, &m);
        assert!(b.download(&sq, 8).unwrap().approx_eq(&want, 1e-4, 1e-4));
    }
}
