//! The execution engine: compiled-executable cache + plan executors.
//!
//! Three execution disciplines, mirroring the paper's comparison:
//!
//! * [`Engine::expm_naive_roundtrip`] — §4.2 "Naïve GPU": one launch per
//!   multiply with a full host round-trip per launch.
//! * [`Engine::expm`] — §4.3 "Our Approach": replay a [`Plan`] keeping all
//!   intermediates as device-resident `PjRtBuffer`s; the matrix crosses the
//!   host↔device boundary exactly twice.
//! * [`Engine::expm_packed`] — our §4.3.8 limit case: the `[acc, base]`
//!   state is packed into one `(2, n, n)` buffer and every exponent bit is
//!   ONE single-output launch (`step_mul`/`step_sq`), so even the fused
//!   square+multiply pair never touches the host.
//!
//! Plus [`Engine::expm_fused_artifact`] (whole `A^N` as a single launch via
//! the `expm{N}` artifacts) and [`Engine::run_matmul_entry`] (tile-sweep
//! ablation).

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::plan::{Plan, Step};
use crate::runtime::artifacts::ArtifactRegistry;
use crate::runtime::literal::{download, literal_to_matrix, matrix_to_literal, upload};
use crate::runtime::{client, Variant};

/// Execution statistics — the quantities Tables 2–5 are about.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExecStats {
    /// Kernel launches (device dispatches).
    pub launches: usize,
    /// Matrix multiplies performed.
    pub multiplies: usize,
    /// Host→device matrix transfers.
    pub h2d_transfers: usize,
    /// Device→host matrix transfers.
    pub d2h_transfers: usize,
    /// Wall-clock seconds for the whole operation.
    pub wall_s: f64,
}

impl ExecStats {
    pub fn merge(&mut self, other: &ExecStats) {
        self.launches += other.launches;
        self.multiplies += other.multiplies;
        self.h2d_transfers += other.h2d_transfers;
        self.d2h_transfers += other.d2h_transfers;
        self.wall_s += other.wall_s;
    }
}

struct ArtifactInfo {
    path: std::path::PathBuf,
    /// Recorded for diagnostics; PJRT output unwrapping is shape-driven.
    #[allow(dead_code)]
    num_outputs: usize,
}

/// Executable cache + plan executors over one PJRT client.
///
/// `Engine` is deliberately `!Send`: PJRT objects live on the thread that
/// created them. The coordinator gives each worker thread its own engine.
pub struct Engine {
    client: xla::PjRtClient,
    variant: Variant,
    /// (op, n) → artifact info for this engine's variant (xla fallback for
    /// ops only lowered in the xla variant, e.g. `expm{N}`).
    info: HashMap<(String, usize), ArtifactInfo>,
    /// Lazily compiled executables.
    exes: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Build an engine from a discovered registry. Executables compile
    /// lazily on first use and are cached for the engine's lifetime.
    pub fn new(registry: &ArtifactRegistry, variant: Variant) -> Result<Engine> {
        let client = client::cpu_client()?;
        let mut info = HashMap::new();
        // xla entries first (fallback), then requested variant overrides
        for pass_variant in ["xla", variant.as_str()] {
            for e in registry.entries() {
                if e.variant == pass_variant && e.dtype == "f32" && e.tile.is_none() {
                    info.insert(
                        (e.op.clone(), e.n),
                        ArtifactInfo { path: registry.path(e), num_outputs: e.num_outputs },
                    );
                }
            }
        }
        Ok(Engine { client, variant, info, exes: HashMap::new() })
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn platform(&self) -> String {
        client::platform_summary(&self.client)
    }

    /// Compile (or fetch from cache) the executable for `(op, n)`.
    fn exe(&mut self, op: &str, n: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (op.to_string(), n);
        if !self.exes.contains_key(&key) {
            let info = self.info.get(&key).ok_or_else(|| {
                MatexpError::Artifact(format!(
                    "no artifact for op={op} n={n} (variant {}); run `make artifacts`",
                    self.variant
                ))
            })?;
            let proto = xla::HloModuleProto::from_text_file(
                info.path.to_str().ok_or_else(|| MatexpError::Artifact("non-utf8 path".into()))?,
            )?;
            let exe = self.client.compile(&xla::XlaComputation::from_proto(&proto))?;
            self.exes.insert(key.clone(), exe);
        }
        Ok(&self.exes[&key])
    }

    /// Pre-compile every op the binary/packed/naive paths need at size `n`
    /// (keeps compile time out of benchmarked regions).
    pub fn warmup(&mut self, n: usize) -> Result<()> {
        for op in ["matmul", "square", "pack2", "step_mul", "step_sq", "unpack0"] {
            self.exe(op, n)?;
        }
        // optional ops — ignore if the artifact set lacks them
        for op in ["sqmul", "square2", "square4"] {
            let _ = self.exe(op, n);
        }
        Ok(())
    }

    /// Compile AND execute every core op once at size `n`. XLA's CPU
    /// runtime finishes thunk initialization on the first execution, which
    /// costs ~4 ms per executable — two orders of magnitude above a warm
    /// n=64 launch. Call this before any timed region (the experiment
    /// harness and ablations do).
    pub fn warmup_exec(&mut self, n: usize) -> Result<()> {
        self.warmup(n)?;
        let id = Matrix::identity(n);
        // binary fused 11 = Init, SqMul, Sq, MulAcc → square/sqmul/matmul
        self.expm(&id, &Plan::binary(11, true))?;
        // chained 64 = square4 + square2
        let _ = self.expm(&id, &Plan::chained(64, &[4, 2]));
        // packed 5 = pack2, step_sq, step_mul, unpack0
        self.expm_packed(&id, 5)?;
        Ok(())
    }

    /// One launch over device buffers returning the single output buffer.
    fn launch_b(
        &mut self,
        op: &str,
        n: usize,
        inputs: &[Rc<xla::PjRtBuffer>],
        stats: &mut ExecStats,
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.exe(op, n)?;
        let mut out = exe.execute_b::<Rc<xla::PjRtBuffer>>(inputs)?;
        stats.launches += 1;
        let mut row = out.pop().ok_or_else(|| MatexpError::Xla("no output".into()))?;
        row.pop().ok_or_else(|| MatexpError::Xla("empty output row".into()))
    }

    /// `a · b` through the AOT matmul executable (one launch).
    pub fn matmul(&mut self, a: &Matrix, b: &Matrix) -> Result<(Matrix, ExecStats)> {
        let n = a.n();
        if b.n() != n {
            return Err(MatexpError::Linalg("matmul size mismatch".into()));
        }
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        let ba = Rc::new(upload(&self.client, a)?);
        let bb = Rc::new(upload(&self.client, b)?);
        stats.h2d_transfers += 2;
        let out = self.launch_b("matmul", n, &[ba, bb], &mut stats)?;
        stats.multiplies += 1;
        let m = download(&out, n)?;
        stats.d2h_transfers += 1;
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((m, stats))
    }

    /// §4.2 Naïve GPU: `power − 1` launches, full host round-trip each
    /// (upload both operands, download the product, every single time).
    pub fn expm_naive_roundtrip(&mut self, a: &Matrix, power: u64) -> Result<(Matrix, ExecStats)> {
        if power == 0 {
            return Err(MatexpError::Plan("power must be >= 1".into()));
        }
        let n = a.n();
        self.exe("matmul", n)?; // compile outside the timed region
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        let mut acc = a.clone();
        for _ in 1..power {
            let lit_acc = matrix_to_literal(&acc)?;
            let lit_a = matrix_to_literal(a)?;
            let exe = self.exe("matmul", n)?;
            let mut out = exe.execute::<xla::Literal>(&[lit_acc, lit_a])?;
            stats.launches += 1;
            stats.multiplies += 1;
            stats.h2d_transfers += 2;
            let buf = out
                .pop()
                .and_then(|mut row| row.pop())
                .ok_or_else(|| MatexpError::Xla("no output".into()))?;
            acc = literal_to_matrix(&buf.to_literal_sync()?, n)?;
            stats.d2h_transfers += 1;
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((acc, stats))
    }

    /// §4.3 Our Approach: replay `plan` with device-resident buffers.
    /// The input crosses host→device once; the result device→host once.
    pub fn expm(&mut self, a: &Matrix, plan: &Plan) -> Result<(Matrix, ExecStats)> {
        plan.validate()?;
        let n = a.n();
        // compile everything the plan needs before the timed region
        for step in &plan.steps {
            if let Some(op) = step.op_name() {
                self.exe(&op, n)?;
            }
        }
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        let mut regs: Vec<Option<Rc<xla::PjRtBuffer>>> = vec![None; plan.n_regs];
        regs[0] = Some(Rc::new(upload(&self.client, a)?));
        stats.h2d_transfers += 1;
        for step in &plan.steps {
            match *step {
                Step::Copy { dst, src } => {
                    regs[dst] = regs[src].clone();
                }
                Step::Mul { dst, lhs, rhs } => {
                    let out = if lhs == rhs {
                        let x = regs[lhs].clone().expect("validated");
                        self.launch_b("square", n, &[x], &mut stats)?
                    } else {
                        let x = regs[lhs].clone().expect("validated");
                        let y = regs[rhs].clone().expect("validated");
                        self.launch_b("matmul", n, &[x, y], &mut stats)?
                    };
                    stats.multiplies += 1;
                    regs[dst] = Some(Rc::new(out));
                }
                Step::SquareChain { reg, k } => {
                    let x = regs[reg].clone().expect("validated");
                    let out = self.launch_b(&format!("square{k}"), n, &[x], &mut stats)?;
                    stats.multiplies += k as usize;
                    regs[reg] = Some(Rc::new(out));
                }
                Step::SqMul { acc, base } => {
                    // the 2-tuple sqmul artifact: PJRT hands back ONE
                    // tuple buffer, so splitting costs a host round-trip —
                    // measured honestly (this is ablation A2's "bad" arm;
                    // the packed path below is the good one).
                    let x = regs[acc].clone().expect("validated");
                    let y = regs[base].clone().expect("validated");
                    let tuple_buf = self.launch_b("sqmul", n, &[x, y], &mut stats)?;
                    stats.multiplies += 2;
                    let parts = tuple_buf.to_literal_sync()?.to_tuple()?;
                    stats.d2h_transfers += 2;
                    if parts.len() != 2 {
                        return Err(MatexpError::Xla(format!(
                            "sqmul returned {}-tuple",
                            parts.len()
                        )));
                    }
                    let mut it = parts.into_iter();
                    let new_acc = literal_to_matrix(&it.next().unwrap(), n)?;
                    let new_base = literal_to_matrix(&it.next().unwrap(), n)?;
                    regs[acc] = Some(Rc::new(upload(&self.client, &new_acc)?));
                    regs[base] = Some(Rc::new(upload(&self.client, &new_base)?));
                    stats.h2d_transfers += 2;
                }
            }
        }
        let out_buf = regs[plan.result].clone().expect("validated: result written");
        let result = download(&out_buf, n)?;
        stats.d2h_transfers += 1;
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((result, stats))
    }

    /// Ablation A2's counterfactual: replay `plan` (same launch schedule as
    /// [`Engine::expm`]) but with a FULL host round-trip per launch — every
    /// operand re-uploaded, every result downloaded. Isolates the paper's
    /// §4.3.8 claim ("data is offloaded only log(N) times") from the
    /// log-vs-linear launch-count effect.
    pub fn expm_plan_roundtrip(&mut self, a: &Matrix, plan: &Plan) -> Result<(Matrix, ExecStats)> {
        plan.validate()?;
        let n = a.n();
        for step in &plan.steps {
            if let Some(op) = step.op_name() {
                if op.starts_with("square") && op != "square" {
                    // square{k} chains: execute as k singles on this path
                    self.exe("square", n)?;
                } else if op == "sqmul" {
                    self.exe("matmul", n)?;
                    self.exe("square", n)?;
                } else {
                    self.exe(&op, n)?;
                }
            }
        }
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        let mut regs: Vec<Option<Matrix>> = vec![None; plan.n_regs];
        regs[0] = Some(a.clone());
        // one launch with per-launch transfers; `ops` follow Step semantics
        let launch = |engine: &mut Engine,
                          op: &str,
                          inputs: &[&Matrix],
                          stats: &mut ExecStats|
         -> Result<Matrix> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|m| matrix_to_literal(m))
                .collect::<Result<_>>()?;
            stats.h2d_transfers += inputs.len();
            let exe = engine.exe(op, n)?;
            let mut out = exe.execute::<xla::Literal>(&lits)?;
            stats.launches += 1;
            stats.multiplies += 1;
            let buf = out
                .pop()
                .and_then(|mut row| row.pop())
                .ok_or_else(|| MatexpError::Xla("no output".into()))?;
            let m = literal_to_matrix(&buf.to_literal_sync()?, n)?;
            stats.d2h_transfers += 1;
            Ok(m)
        };
        for step in &plan.steps {
            match *step {
                Step::Copy { dst, src } => regs[dst] = regs[src].clone(),
                Step::Mul { dst, lhs, rhs } => {
                    let out = if lhs == rhs {
                        let x = regs[lhs].clone().expect("validated");
                        launch(self, "square", &[&x], &mut stats)?
                    } else {
                        let x = regs[lhs].clone().expect("validated");
                        let y = regs[rhs].clone().expect("validated");
                        launch(self, "matmul", &[&x, &y], &mut stats)?
                    };
                    regs[dst] = Some(out);
                }
                Step::SqMul { acc, base } => {
                    let a0 = regs[acc].clone().expect("validated");
                    let b0 = regs[base].clone().expect("validated");
                    regs[acc] = Some(launch(self, "matmul", &[&a0, &b0], &mut stats)?);
                    regs[base] = Some(launch(self, "square", &[&b0], &mut stats)?);
                }
                Step::SquareChain { reg, k } => {
                    for _ in 0..k {
                        let b = regs[reg].clone().expect("validated");
                        regs[reg] = Some(launch(self, "square", &[&b], &mut stats)?);
                    }
                }
            }
        }
        let result = regs[plan.result].take().expect("validated: result written");
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((result, stats))
    }

    /// Packed-state binary exponentiation: the `[acc, base]` pair lives in
    /// one `(2, n, n)` device buffer; every exponent bit is one launch and
    /// NOTHING round-trips until the final download.
    pub fn expm_packed(&mut self, a: &Matrix, power: u64) -> Result<(Matrix, ExecStats)> {
        if power == 0 {
            return Err(MatexpError::Plan("power must be >= 1".into()));
        }
        let n = a.n();
        self.warmup(n)?;
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        if power == 1 {
            stats.wall_s = t0.elapsed().as_secs_f64();
            return Ok((a.clone(), stats));
        }
        let tz = power.trailing_zeros();
        let mut base = Rc::new(upload(&self.client, a)?);
        stats.h2d_transfers += 1;
        for _ in 0..tz {
            base = Rc::new(self.launch_b("square", n, &[base], &mut stats)?);
            stats.multiplies += 1;
        }
        // pack consumes the lowest set bit: acc = base = A^(2^tz)
        let mut state = Rc::new(self.launch_b("pack2", n, &[base], &mut stats)?);
        let mut q = (power >> tz) >> 1;
        while q > 0 {
            let op = if q & 1 == 1 { "step_mul" } else { "step_sq" };
            state = Rc::new(self.launch_b(op, n, &[state], &mut stats)?);
            stats.multiplies += if q & 1 == 1 { 2 } else { 1 };
            q >>= 1;
        }
        let acc = Rc::new(self.launch_b("unpack0", n, &[state], &mut stats)?);
        let result = download(&acc, n)?;
        stats.d2h_transfers += 1;
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((result, stats))
    }

    /// Whole `A^power` as one launch, if an `expm{power}` artifact exists.
    pub fn expm_fused_artifact(&mut self, a: &Matrix, power: u64) -> Result<(Matrix, ExecStats)> {
        let n = a.n();
        let op = format!("expm{power}");
        self.exe(&op, n)?;
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        let buf = Rc::new(upload(&self.client, a)?);
        stats.h2d_transfers += 1;
        let out = self.launch_b(&op, n, &[buf], &mut stats)?;
        stats.multiplies += Plan::binary(power, false).multiplies();
        let result = download(&out, n)?;
        stats.d2h_transfers += 1;
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((result, stats))
    }

    /// Run an arbitrary 2-input matmul artifact by manifest name (the
    /// tile-sweep ablation needs the tiled entries `find` hides).
    pub fn run_matmul_entry(
        &mut self,
        registry: &ArtifactRegistry,
        name: &str,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<(Matrix, ExecStats)> {
        let entry = registry
            .entries()
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| MatexpError::Artifact(format!("no artifact named {name}")))?;
        let key = (format!("entry:{name}"), entry.n);
        if !self.exes.contains_key(&key) {
            let path = registry.path(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| MatexpError::Artifact("non-utf8 path".into()))?,
            )?;
            let exe = self.client.compile(&xla::XlaComputation::from_proto(&proto))?;
            self.exes.insert(key.clone(), exe);
        }
        let n = entry.n;
        let mut stats = ExecStats::default();
        let t0 = Instant::now();
        let ba = Rc::new(upload(&self.client, a)?);
        let bb = Rc::new(upload(&self.client, b)?);
        stats.h2d_transfers += 2;
        let exe = &self.exes[&key];
        let mut out = exe.execute_b::<Rc<xla::PjRtBuffer>>(&[ba, bb])?;
        stats.launches += 1;
        stats.multiplies += 1;
        let buf = out
            .pop()
            .and_then(|mut row| row.pop())
            .ok_or_else(|| MatexpError::Xla("no output".into()))?;
        let m = download(&buf, n)?;
        stats.d2h_transfers += 1;
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((m, stats))
    }
}
