//! The execution engine: replays [`Plan`]s on any [`Backend`], with the
//! launch/transfer/residency accounting the paper's tables are about.
//!
//! **Submit work through the one execution surface** —
//! [`crate::exec::Executor`]: `engine.run(Submission::expm(a, N))`. The
//! method on the submission picks the discipline; the engine's internal
//! strategy dispatch mirrors the paper's comparison:
//!
//! * `Method::NaiveGpu` — §4.2 "Naïve GPU": one launch per multiply with
//!   a full host round-trip per launch.
//! * `Method::Ours` (and friends) — §4.3 "Our Approach": replay a
//!   [`Plan`] keeping all intermediates as device-resident buffers; the
//!   matrix crosses the host↔device boundary exactly twice, and plan
//!   replay ping-pongs recycled arena buffers instead of allocating per
//!   step.
//! * `Method::OursPacked` — our §4.3.8 limit case: the `[acc, base]`
//!   state is packed into one pair buffer and every exponent bit is ONE
//!   single-output launch (`StepMul`/`StepSq`), so even the fused
//!   square+multiply pair never touches the host.
//!
//! Plus `Method::FusedArtifact` (whole `A^N` as a single launch) and
//! `Method::PlanRoundtrip` (ablation A2's counterfactual). The legacy
//! per-discipline entry points were removed in 0.4.0 after their
//! one-release deprecation window; the old→new migration table lives in
//! the crate docs ([`crate`]).
//!
//! Every `prepare` the engine issues goes through its per-backend
//! [`crate::cache::PreparedSet`] (cache tier 2): a `(KernelOp, n)` pair
//! that prepared successfully once is never re-prepared on this backend,
//! so warm launches skip compile/validation work entirely. Only successes
//! are recorded — an [`MatexpError::UnsupportedOp`] stays retryable,
//! preserving warmup's optional-op policy.
//!
//! The engine is generic over the backend (static dispatch); use
//! [`Engine::cpu`] / [`Engine::sim`] / [`Engine::from_config`] — or, with
//! the `xla` feature, [`Engine::pjrt`] — to construct one.

use std::time::Instant;

use crate::cache::PreparedSet;
use crate::error::{MatexpError, Result};
use crate::linalg::expm::CpuAlgo;
use crate::linalg::matrix::Matrix;
use crate::plan::{Plan, Step};
use crate::runtime::backend::Backend;
use crate::runtime::cpu::CpuBackend;
use crate::runtime::op::KernelOp;
use crate::runtime::sim::SimBackend;
use crate::trace;

/// One device's share of an execution (filled by the multi-device
/// [`crate::pool`] layer; empty for single-backend engines).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceStats {
    /// Device name, e.g. `sim#1` or `cpu#0`.
    pub device: String,
    /// Kernel launches this device performed.
    pub launches: usize,
    /// Matrix multiplies this device performed (tile-level multiplies in
    /// sharded mode, so they can exceed the plan's logical count).
    pub multiplies: usize,
    /// Host→device transfers this device performed.
    pub h2d_transfers: usize,
    /// Device→host transfers this device performed.
    pub d2h_transfers: usize,
    /// Host-edge bytes this device's data path copied.
    pub bytes_copied: u64,
    /// Launch outputs this device served from recycled arena buffers.
    pub buffers_recycled: u64,
    /// High-water mark of this device's resident buffer bytes.
    pub peak_resident_bytes: u64,
    /// Seconds this device was busy (simulated on timing-model devices).
    pub wall_s: f64,
}

impl DeviceStats {
    fn absorb(&mut self, other: &DeviceStats) {
        self.launches += other.launches;
        self.multiplies += other.multiplies;
        self.h2d_transfers += other.h2d_transfers;
        self.d2h_transfers += other.d2h_transfers;
        self.bytes_copied += other.bytes_copied;
        self.buffers_recycled += other.buffers_recycled;
        self.peak_resident_bytes = self.peak_resident_bytes.max(other.peak_resident_bytes);
        self.wall_s += other.wall_s;
    }
}

/// Execution statistics — the quantities Tables 2–5 are about.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExecStats {
    /// Kernel launches (device dispatches).
    pub launches: usize,
    /// Matrix multiplies performed.
    pub multiplies: usize,
    /// Host→device matrix transfers.
    pub h2d_transfers: usize,
    /// Device→host matrix transfers.
    pub d2h_transfers: usize,
    /// Bytes that crossed the host↔device edge (the residency layer's
    /// ground truth: a device-resident run copies exactly the input in
    /// and the result out; the clone-per-launch counterfactual copies
    /// O(launches·n²)).
    pub bytes_copied: u64,
    /// Launch outputs served from recycled arena buffers instead of fresh
    /// allocations (plan replay ping-pongs two resident buffers).
    pub buffers_recycled: u64,
    /// High-water mark of live device-buffer bytes during the run. On a
    /// device pool this is the sum of the per-device peaks (devices hold
    /// their buffers concurrently), so it upper-bounds the true
    /// all-devices-at-once maximum.
    pub peak_resident_bytes: u64,
    /// Wall-clock seconds for the whole operation (simulated seconds on
    /// a timing-model backend). On a device pool this is the *critical
    /// path* (max over devices per step), so it can be smaller than the
    /// sum of the per-device walls.
    pub wall_s: f64,
    /// Per-device breakdown when executed by a [`crate::pool::DevicePool`];
    /// empty on single-backend engines. Launch/transfer counts across the
    /// entries sum to the totals above.
    pub per_device: Vec<DeviceStats>,
    /// Microseconds queued in the serving coordinator before a worker
    /// picked the request up (0 on direct engine/pool execution).
    pub queue_us: u64,
    /// Microseconds spent in strategy/plan selection (the
    /// [`crate::trace::Stage::Plan`] accumulator).
    pub plan_us: u64,
    /// Microseconds spent in cold `Backend::prepare` calls (warm prepared
    /// cache hits bill nothing here).
    pub prepare_us: u64,
    /// Microseconds spent inside kernel launches, summed over the
    /// request's launch chain.
    pub launch_us: u64,
    /// Microseconds the server spent decoding the request and encoding
    /// the response (0 on local submissions that never touch the wire).
    pub wire_us: u64,
}

impl ExecStats {
    /// Accumulate another execution's stats into this one (counters add;
    /// the resident peak takes the max; per-device breakdowns fold by
    /// device name).
    pub fn merge(&mut self, other: &ExecStats) {
        self.launches += other.launches;
        self.multiplies += other.multiplies;
        self.h2d_transfers += other.h2d_transfers;
        self.d2h_transfers += other.d2h_transfers;
        self.bytes_copied += other.bytes_copied;
        self.buffers_recycled += other.buffers_recycled;
        self.peak_resident_bytes = self.peak_resident_bytes.max(other.peak_resident_bytes);
        self.wall_s += other.wall_s;
        self.queue_us += other.queue_us;
        self.plan_us += other.plan_us;
        self.prepare_us += other.prepare_us;
        self.launch_us += other.launch_us;
        self.wire_us += other.wire_us;
        for d in &other.per_device {
            self.merge_device(d);
        }
    }

    /// Fold one device's share into the per-device breakdown (keyed by
    /// device name).
    pub fn merge_device(&mut self, d: &DeviceStats) {
        match self.per_device.iter_mut().find(|mine| mine.device == d.device) {
            Some(mine) => mine.absorb(d),
            None => self.per_device.push(d.clone()),
        }
    }
}

/// Plan executor over one execution backend.
pub struct Engine<B: Backend> {
    backend: B,
    /// Tier-2 cache: `(op, n)` pairs this backend already prepared.
    prepared: PreparedSet,
}

/// Engine on the default pure-Rust backend.
pub type CpuEngine = Engine<CpuBackend>;
/// Engine on the Tesla C2050 timing model.
pub type SimEngine = Engine<SimBackend>;
/// Engine on the runtime-selected backend (coordinator / CLI).
pub type AnyEngine = Engine<crate::runtime::any::AnyBackend>;

impl Engine<CpuBackend> {
    /// Pure-Rust engine with the given matmul variant.
    pub fn cpu(algo: CpuAlgo) -> CpuEngine {
        Engine::new(CpuBackend::new(algo))
    }
}

impl Engine<SimBackend> {
    /// Timing-model engine (spec-sheet Tesla C2050).
    pub fn sim() -> SimEngine {
        Engine::new(SimBackend::tesla_c2050())
    }
}

impl Engine<crate::runtime::any::AnyBackend> {
    /// Engine on whatever backend the config selects.
    pub fn from_config(cfg: &crate::config::MatexpConfig) -> Result<AnyEngine> {
        Ok(Engine::new(crate::runtime::any::AnyBackend::from_config(cfg)?))
    }
}

#[cfg(feature = "xla")]
impl Engine<crate::runtime::pjrt::PjrtBackend> {
    /// PJRT engine over a discovered artifact registry.
    pub fn pjrt(
        registry: &crate::runtime::artifacts::ArtifactRegistry,
        variant: crate::runtime::Variant,
    ) -> Result<Engine<crate::runtime::pjrt::PjrtBackend>> {
        Ok(Engine::new(crate::runtime::pjrt::PjrtBackend::new(registry, variant)?))
    }
}

impl<B: Backend> Engine<B> {
    /// Wrap a backend in a plan-replaying engine (fresh prepared cache).
    pub fn new(backend: B) -> Engine<B> {
        Engine { backend, prepared: PreparedSet::new() }
    }

    /// The underlying execution backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable access to the backend. Skipping the engine's prepare path
    /// is fine — backends keep `prepare` idempotent — but state that
    /// *invalidates* prepared executables must not be mutated this way.
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Human-readable description of the execution substrate.
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// `Backend::prepare` behind the tier-2 prepared cache: a pair that
    /// prepared successfully once on this backend is skipped thereafter.
    /// Failures are NOT recorded, so optional ops stay retryable.
    pub(crate) fn prepare_cached(&mut self, op: KernelOp, n: usize) -> Result<()> {
        if self.prepared.check(op, n) {
            trace::event(trace::SpanKind::CacheHit(trace::Tier::Prepared), trace::current(), n);
            return Ok(());
        }
        trace::event(trace::SpanKind::CacheMiss(trace::Tier::Prepared), trace::current(), n);
        let t0 = trace::now_us();
        self.backend.prepare(op, n)?;
        trace::add_stage(trace::Stage::Prepare, trace::now_us().saturating_sub(t0));
        self.prepared.record(op, n);
        trace::event(trace::SpanKind::CacheStore(trace::Tier::Prepared), trace::current(), n);
        Ok(())
    }

    /// Distinct `(op, n)` pairs prepared so far (diagnostics/tests).
    pub fn prepared_ops(&self) -> usize {
        self.prepared.len()
    }

    /// Start a timed region: reset the simulated clock and residency
    /// counters so warmup/compile work is not billed to the measurement.
    fn begin_timed(&mut self) -> Instant {
        let _ = self.backend.take_sim_time();
        let _ = self.backend.take_residency();
        Instant::now()
    }

    /// End a timed region: record wall seconds (simulated if the backend
    /// models time) and drain the backend's residency counters into the
    /// stats.
    fn end_timed(&mut self, t0: Instant, stats: &mut ExecStats) {
        stats.wall_s = self
            .backend
            .take_sim_time()
            .unwrap_or_else(|| t0.elapsed().as_secs_f64());
        let residency = self.backend.take_residency();
        stats.bytes_copied = residency.bytes_copied;
        stats.buffers_recycled = residency.buffers_recycled;
        stats.peak_resident_bytes = residency.peak_resident_bytes;
    }

    /// One launch over device buffers, with launch accounting.
    fn launch_b(
        &mut self,
        op: KernelOp,
        n: usize,
        inputs: &[B::Buffer],
        stats: &mut ExecStats,
    ) -> Result<B::Buffer> {
        let t0 = trace::now_us();
        let out = self.backend.launch(op, n, inputs)?;
        trace::add_stage(trace::Stage::Launch, trace::now_us().saturating_sub(t0));
        trace::record_launch(trace::current(), op, n, t0);
        stats.launches += 1;
        Ok(out)
    }

    /// Prepare (compile/cache) every op the binary/packed/naive paths
    /// need at size `n` (keeps compile time out of benchmarked regions).
    /// Optional ops a backend genuinely lacks
    /// ([`MatexpError::UnsupportedOp`]) are skipped; any other prepare
    /// failure is real and propagates.
    pub fn warmup(&mut self, n: usize) -> Result<()> {
        const REQUIRED: [KernelOp; 6] = [
            KernelOp::Matmul,
            KernelOp::Square,
            KernelOp::Pack2,
            KernelOp::StepMul,
            KernelOp::StepSq,
            KernelOp::Unpack0,
        ];
        const OPTIONAL: [KernelOp; 3] =
            [KernelOp::SqMul, KernelOp::SquareChain(2), KernelOp::SquareChain(4)];
        for op in REQUIRED {
            self.prepare_cached(op, n)?;
        }
        for op in OPTIONAL {
            match self.prepare_cached(op, n) {
                Ok(()) | Err(MatexpError::UnsupportedOp(_)) => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Prepare AND execute every core op once at size `n`. XLA's CPU
    /// runtime finishes thunk initialization on the first execution
    /// (~4 ms per executable — two orders of magnitude above a warm n=64
    /// launch); pure-Rust backends warm caches/branch predictors. Call
    /// this before any timed region (the experiment harness does).
    pub fn warmup_exec(&mut self, n: usize) -> Result<()> {
        self.warmup(n)?;
        let id = Matrix::identity(n);
        // optional-op replays follow warmup's policy: an op the backend
        // genuinely lacks is skippable, any other failure is real
        let optional_exec = |result: Result<(Matrix, ExecStats)>| match result {
            Ok(_) | Err(MatexpError::UnsupportedOp(_)) => Ok(()),
            Err(e) => Err(e),
        };
        // binary fused 11 = Init, SqMul, Sq, MulAcc → square/sqmul/matmul
        // (sqmul is optional — some artifact sets don't ship it)
        let fused = self.run_plan(&id, &Plan::binary(11, true));
        optional_exec(fused)?;
        // chained 64 = square4 + square2 (optional chain kernels)
        let chained = self.run_plan(&id, &Plan::chained(64, &[4, 2]));
        optional_exec(chained)?;
        // packed 5 = pack2, step_sq, step_mul, unpack0 — all required ops
        self.run_packed(&id, 5)?;
        Ok(())
    }

    /// `a · b` through the backend's matmul op (one launch). A low-level
    /// primitive (tile sweeps, kernel benches) — exponentiation work goes
    /// through the [`crate::exec::Executor`] surface.
    pub fn matmul(&mut self, a: &Matrix, b: &Matrix) -> Result<(Matrix, ExecStats)> {
        let n = a.n();
        if b.n() != n {
            return Err(MatexpError::Linalg("matmul size mismatch".into()));
        }
        self.prepare_cached(KernelOp::Matmul, n)?;
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        let ba = self.backend.upload(a.clone())?;
        let bb = self.backend.upload(b.clone())?;
        stats.h2d_transfers += 2;
        let out = self.launch_b(KernelOp::Matmul, n, &[ba, bb], &mut stats)?;
        stats.multiplies += 1;
        let m = self.backend.download(&out, n)?;
        stats.d2h_transfers += 1;
        self.end_timed(t0, &mut stats);
        Ok((m, stats))
    }

    /// §4.2 Naïve GPU: `power − 1` launches, full host round-trip each
    /// (upload both operands, download the product, every single time).
    pub(crate) fn run_naive_roundtrip(
        &mut self,
        a: &Matrix,
        power: u64,
    ) -> Result<(Matrix, ExecStats)> {
        if power == 0 {
            return Err(MatexpError::Plan("power must be >= 1".into()));
        }
        let n = a.n();
        self.prepare_cached(KernelOp::Matmul, n)?; // compile outside the timed region
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        let mut acc = a.clone();
        for _ in 1..power {
            let b_acc = self.backend.upload(acc)?;
            let b_a = self.backend.upload(a.clone())?;
            stats.h2d_transfers += 2;
            let out = self.launch_b(KernelOp::Matmul, n, &[b_acc, b_a], &mut stats)?;
            stats.multiplies += 1;
            acc = self.backend.download(&out, n)?;
            stats.d2h_transfers += 1;
        }
        self.end_timed(t0, &mut stats);
        Ok((acc, stats))
    }

    /// §4.3 Our Approach: replay `plan` with device-resident buffers.
    /// The input crosses host→device once; the result device→host once
    /// (plus whatever a `SqMul` tuple split costs on this backend). The
    /// register file drops stale buffers as it overwrites them, so the
    /// backend's arena ping-pongs recycled allocations instead of growing.
    pub(crate) fn run_plan(&mut self, a: &Matrix, plan: &Plan) -> Result<(Matrix, ExecStats)> {
        plan.validate()?;
        let n = a.n();
        // prepare everything the plan needs before the timed region
        // (warm engines skip this wholesale via the prepared cache)
        for step in &plan.steps {
            if let Some(op) = step.op() {
                self.prepare_cached(op, n)?;
            }
        }
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        let mut regs: Vec<Option<B::Buffer>> = vec![None; plan.n_regs];
        regs[0] = Some(self.backend.upload(a.clone())?);
        stats.h2d_transfers += 1;
        for step in &plan.steps {
            match *step {
                Step::Copy { dst, src } => {
                    regs[dst] = regs[src].clone();
                }
                Step::Mul { dst, lhs, rhs } => {
                    let out = if lhs == rhs {
                        let x = regs[lhs].clone().expect("validated");
                        self.launch_b(KernelOp::Square, n, &[x], &mut stats)?
                    } else {
                        let x = regs[lhs].clone().expect("validated");
                        let y = regs[rhs].clone().expect("validated");
                        self.launch_b(KernelOp::Matmul, n, &[x, y], &mut stats)?
                    };
                    stats.multiplies += 1;
                    regs[dst] = Some(out);
                }
                Step::SquareChain { reg, k } => {
                    let x = regs[reg].take().expect("validated");
                    let out = self.launch_b(KernelOp::SquareChain(k), n, &[x], &mut stats)?;
                    stats.multiplies += k as usize;
                    regs[reg] = Some(out);
                }
                Step::SqMul { acc, base } => {
                    // clone, don't take: `acc == base` is a valid aliased
                    // step (buffer clones are pointer clones anyway)
                    let x = regs[acc].clone().expect("validated");
                    let y = regs[base].clone().expect("validated");
                    let pair = self.launch_b(KernelOp::SqMul, n, &[x, y], &mut stats)?;
                    stats.multiplies += 2;
                    let split = self.backend.split_pair(pair, n)?;
                    stats.h2d_transfers += split.h2d_transfers;
                    stats.d2h_transfers += split.d2h_transfers;
                    regs[acc] = Some(split.first);
                    regs[base] = Some(split.second);
                }
            }
        }
        let out_buf = regs[plan.result].take().expect("validated: result written");
        let result = self.backend.download(&out_buf, n)?;
        stats.d2h_transfers += 1;
        drop(out_buf);
        self.end_timed(t0, &mut stats);
        Ok((result, stats))
    }

    /// Ablation A2's counterfactual: replay `plan` (same launch schedule
    /// as the device-resident path) but with a FULL host round-trip per
    /// launch — every operand re-uploaded, every result downloaded.
    /// Isolates the paper's §4.3.8 claim ("data is offloaded only log(N)
    /// times") from the log-vs-linear launch-count effect.
    pub(crate) fn run_plan_roundtrip(
        &mut self,
        a: &Matrix,
        plan: &Plan,
    ) -> Result<(Matrix, ExecStats)> {
        plan.validate()?;
        let n = a.n();
        // square{k} chains run as k singles and sqmul as matmul+square on
        // this path, so only the two base ops are needed
        self.prepare_cached(KernelOp::Matmul, n)?;
        self.prepare_cached(KernelOp::Square, n)?;
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        let mut regs: Vec<Option<Matrix>> = vec![None; plan.n_regs];
        regs[0] = Some(a.clone());
        for step in &plan.steps {
            match *step {
                Step::Copy { dst, src } => regs[dst] = regs[src].clone(),
                Step::Mul { dst, lhs, rhs } => {
                    let out = if lhs == rhs {
                        let x = regs[lhs].clone().expect("validated");
                        self.roundtrip_launch(KernelOp::Square, n, &[&x], &mut stats)?
                    } else {
                        let x = regs[lhs].clone().expect("validated");
                        let y = regs[rhs].clone().expect("validated");
                        self.roundtrip_launch(KernelOp::Matmul, n, &[&x, &y], &mut stats)?
                    };
                    regs[dst] = Some(out);
                }
                Step::SqMul { acc, base } => {
                    let a0 = regs[acc].clone().expect("validated");
                    let b0 = regs[base].clone().expect("validated");
                    regs[acc] =
                        Some(self.roundtrip_launch(KernelOp::Matmul, n, &[&a0, &b0], &mut stats)?);
                    regs[base] =
                        Some(self.roundtrip_launch(KernelOp::Square, n, &[&b0], &mut stats)?);
                }
                Step::SquareChain { reg, k } => {
                    for _ in 0..k {
                        let b = regs[reg].clone().expect("validated");
                        regs[reg] =
                            Some(self.roundtrip_launch(KernelOp::Square, n, &[&b], &mut stats)?);
                    }
                }
            }
        }
        let result = regs[plan.result].take().expect("validated: result written");
        self.end_timed(t0, &mut stats);
        Ok((result, stats))
    }

    /// One launch with per-launch transfers (the roundtrip discipline).
    fn roundtrip_launch(
        &mut self,
        op: KernelOp,
        n: usize,
        inputs: &[&Matrix],
        stats: &mut ExecStats,
    ) -> Result<Matrix> {
        let bufs: Vec<B::Buffer> = inputs
            .iter()
            .map(|m| self.backend.upload((*m).clone()))
            .collect::<Result<_>>()?;
        stats.h2d_transfers += inputs.len();
        let out = self.launch_b(op, n, &bufs, stats)?;
        stats.multiplies += 1;
        let m = self.backend.download(&out, n)?;
        stats.d2h_transfers += 1;
        Ok(m)
    }

    /// Packed-state binary exponentiation: the `[acc, base]` pair lives in
    /// one packed device buffer; every exponent bit is one launch and
    /// NOTHING round-trips until the final download.
    pub(crate) fn run_packed(&mut self, a: &Matrix, power: u64) -> Result<(Matrix, ExecStats)> {
        if power == 0 {
            return Err(MatexpError::Plan("power must be >= 1".into()));
        }
        let n = a.n();
        self.warmup(n)?;
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        if power == 1 {
            self.end_timed(t0, &mut stats);
            return Ok((a.clone(), stats));
        }
        let tz = power.trailing_zeros();
        let mut base = self.backend.upload(a.clone())?;
        stats.h2d_transfers += 1;
        for _ in 0..tz {
            base = self.launch_b(KernelOp::Square, n, &[base], &mut stats)?;
            stats.multiplies += 1;
        }
        // pack consumes the lowest set bit: acc = base = A^(2^tz)
        let mut state = self.launch_b(KernelOp::Pack2, n, &[base], &mut stats)?;
        let mut q = (power >> tz) >> 1;
        while q > 0 {
            let op = if q & 1 == 1 { KernelOp::StepMul } else { KernelOp::StepSq };
            state = self.launch_b(op, n, &[state], &mut stats)?;
            stats.multiplies += op.multiplies();
            q >>= 1;
        }
        let acc = self.launch_b(KernelOp::Unpack0, n, &[state], &mut stats)?;
        let result = self.backend.download(&acc, n)?;
        stats.d2h_transfers += 1;
        drop(acc);
        self.end_timed(t0, &mut stats);
        Ok((result, stats))
    }

    /// Whole `A^power` as one launch, if the backend ships a fused
    /// `expm{power}` kernel (see [`crate::runtime::FUSED_EXPM_POWERS`]).
    pub(crate) fn run_fused(&mut self, a: &Matrix, power: u64) -> Result<(Matrix, ExecStats)> {
        let n = a.n();
        let op = KernelOp::Expm(power);
        self.prepare_cached(op, n)?;
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        let buf = self.backend.upload(a.clone())?;
        stats.h2d_transfers += 1;
        let out = self.launch_b(op, n, &[buf], &mut stats)?;
        stats.multiplies += op.multiplies();
        let result = self.backend.download(&out, n)?;
        stats.d2h_transfers += 1;
        self.end_timed(t0, &mut stats);
        Ok((result, stats))
    }
}

#[cfg(feature = "xla")]
impl Engine<crate::runtime::pjrt::PjrtBackend> {
    /// Run an arbitrary 2-input matmul artifact by manifest name (the
    /// tile-sweep ablation needs the tiled entries `find` hides).
    pub fn run_matmul_entry(
        &mut self,
        registry: &crate::runtime::artifacts::ArtifactRegistry,
        name: &str,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<(Matrix, ExecStats)> {
        let n = self.backend.prepare_entry(registry, name)?;
        let mut stats = ExecStats::default();
        let t0 = self.begin_timed();
        let ba = self.backend.upload(a.clone())?;
        let bb = self.backend.upload(b.clone())?;
        stats.h2d_transfers += 2;
        let out = self.backend.launch_entry(name, n, &[ba, bb])?;
        stats.launches += 1;
        stats.multiplies += 1;
        let m = self.backend.download(&out, n)?;
        stats.d2h_transfers += 1;
        self.end_timed(t0, &mut stats);
        Ok((m, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn oracle(a: &Matrix, power: u64) -> Matrix {
        linalg::expm::expm(a, power, CpuAlgo::Ikj).unwrap()
    }

    #[test]
    fn cpu_engine_replays_all_plan_kinds() {
        let mut e = Engine::cpu(CpuAlgo::Naive);
        let a = Matrix::random_spectral(12, 0.95, 3);
        for power in [1u64, 2, 13, 100] {
            let want = oracle(&a, power);
            for plan in [
                Plan::naive(power),
                Plan::binary(power, false),
                Plan::binary(power, true),
                Plan::chained(power, &[4, 2]),
                Plan::addition_chain(power),
            ] {
                let (got, stats) = e.run_plan(&a, &plan).unwrap();
                assert!(
                    got.approx_eq(&want, 1e-4, 1e-4),
                    "{:?} N={power}: diff {}",
                    plan.kind,
                    got.max_abs_diff(&want)
                );
                assert_eq!(stats.launches, plan.launches(), "{:?} N={power}", plan.kind);
                assert_eq!(stats.multiplies, plan.multiplies(), "{:?} N={power}", plan.kind);
                assert_eq!(stats.h2d_transfers, 1, "{:?} N={power}", plan.kind);
                assert_eq!(stats.d2h_transfers, 1, "{:?} N={power}", plan.kind);
            }
        }
    }

    #[test]
    fn naive_roundtrip_accounting_on_cpu() {
        let mut e = Engine::cpu(CpuAlgo::Naive);
        let a = Matrix::random_spectral(8, 0.9, 5);
        let (got, stats) = e.run_naive_roundtrip(&a, 16).unwrap();
        assert!(got.approx_eq(&oracle(&a, 16), 1e-4, 1e-4));
        assert_eq!(stats.launches, 15);
        assert_eq!(stats.multiplies, 15);
        assert_eq!(stats.h2d_transfers, 30);
        assert_eq!(stats.d2h_transfers, 15);
        // the roundtrip discipline's data path copies every edge crossing
        assert_eq!(stats.bytes_copied, 45 * 8 * 8 * 4);
    }

    #[test]
    fn packed_touches_host_exactly_twice() {
        let mut e = Engine::cpu(CpuAlgo::Naive);
        let a = Matrix::random_spectral(8, 0.9, 6);
        let (got, stats) = e.run_packed(&a, 100).unwrap();
        assert!(got.approx_eq(&oracle(&a, 100), 1e-4, 1e-4));
        assert_eq!(stats.h2d_transfers, 1);
        assert_eq!(stats.d2h_transfers, 1);
        assert_eq!(stats.multiplies, Plan::binary(100, false).multiplies());
        // residency ground truth: ONLY the two host-edge transfers copy
        assert_eq!(stats.bytes_copied, 2 * 8 * 8 * 4);
        assert!(stats.buffers_recycled > 0, "{stats:?}");
        assert!(stats.peak_resident_bytes > 0);
    }

    #[test]
    fn resident_replay_recycles_buffers() {
        let mut e = Engine::cpu(CpuAlgo::Naive);
        let a = Matrix::random_spectral(16, 0.9, 7);
        let (_, resident) = e.run_plan(&a, &Plan::binary(1024, false)).unwrap();
        assert_eq!(resident.bytes_copied, 2 * 16 * 16 * 4);
        // 10 squarings ping-pong the arena: most launches recycle
        assert!(resident.buffers_recycled >= 7, "{resident:?}");
        // peak residency stays a few buffers, not O(launches)
        assert!(resident.peak_resident_bytes <= 4 * 16 * 16 * 4, "{resident:?}");
        let (_, roundtrip) = e.run_plan_roundtrip(&a, &Plan::binary(1024, false)).unwrap();
        assert!(
            roundtrip.bytes_copied >= 10 * resident.bytes_copied,
            "clone-per-launch {roundtrip:?} vs resident {resident:?}"
        );
    }

    #[test]
    fn sim_engine_reports_simulated_time() {
        let mut e = Engine::sim();
        let a = Matrix::random_spectral(64, 0.9, 7);
        let (_, ours) = e.run_plan(&a, &Plan::binary(512, false)).unwrap();
        let (_, naive) = e.run_naive_roundtrip(&a, 512).unwrap();
        // simulated seconds, not wall: the 2012 C2050 model puts the naive
        // discipline far behind the device-resident one
        assert!(ours.wall_s > 0.0);
        assert!(naive.wall_s > ours.wall_s * 5.0, "naive {} vs ours {}", naive.wall_s, ours.wall_s);
    }

    #[test]
    fn fused_artifact_availability_mirrors_shipped_powers() {
        let mut e = Engine::cpu(CpuAlgo::Naive);
        let a = Matrix::random_spectral(8, 0.9, 8);
        let (got, stats) = e.run_fused(&a, 64).unwrap();
        assert_eq!(stats.launches, 1);
        assert!(got.approx_eq(&oracle(&a, 64), 1e-4, 1e-4));
        assert!(e.run_fused(&a, 65).is_err());
    }

    /// Tier-2 prepared cache: a warm engine never re-prepares, and the
    /// skip is observable through the per-engine counters.
    #[test]
    fn prepared_cache_skips_warm_prepares() {
        let mut e = Engine::cpu(CpuAlgo::Naive);
        assert_eq!(e.prepared_ops(), 0);
        e.warmup(8).unwrap();
        let after_first = e.prepared_ops();
        assert!(after_first >= 6, "all required ops recorded: {after_first}");
        let cold_misses = e.prepared.misses();
        e.warmup(8).unwrap();
        assert_eq!(e.prepared.misses(), cold_misses, "second warmup prepares nothing new");
        assert!(e.prepared.hits() >= 6, "warm warmup is all hits");
        // a new size is cold again
        e.warmup(16).unwrap();
        assert!(e.prepared_ops() > after_first);
    }

    /// Backend wrapper that fails `prepare` for [`KernelOp::SqMul`] with a
    /// configurable error kind — exercises warmup's optional-op policy.
    struct FlakyPrepare {
        inner: CpuBackend,
        hard: bool,
    }

    impl Backend for FlakyPrepare {
        type Buffer = crate::runtime::cpu::CpuBuffer;

        fn name(&self) -> &'static str {
            "flaky"
        }

        fn platform(&self) -> String {
            "flaky-prepare test backend".into()
        }

        fn prepare(&mut self, op: KernelOp, n: usize) -> Result<()> {
            if op == KernelOp::SqMul {
                return Err(if self.hard {
                    MatexpError::Backend("compile crashed".into())
                } else {
                    MatexpError::UnsupportedOp("sqmul not shipped".into())
                });
            }
            self.inner.prepare(op, n)
        }

        fn upload(&mut self, m: Matrix) -> Result<Self::Buffer> {
            self.inner.upload(m)
        }

        fn download(&mut self, buf: &Self::Buffer, n: usize) -> Result<Matrix> {
            self.inner.download(buf, n)
        }

        fn launch(&mut self, op: KernelOp, n: usize, inputs: &[Self::Buffer]) -> Result<Self::Buffer> {
            self.inner.launch(op, n, inputs)
        }

        fn split_pair(
            &mut self,
            buf: Self::Buffer,
            n: usize,
        ) -> Result<crate::runtime::SplitPair<Self::Buffer>> {
            self.inner.split_pair(buf, n)
        }
    }

    #[test]
    fn warmup_skips_unsupported_but_propagates_real_failures() {
        let mut soft = Engine::new(FlakyPrepare {
            inner: CpuBackend::new(CpuAlgo::Naive),
            hard: false,
        });
        soft.warmup(8).expect("a genuinely absent optional op is skippable");

        let mut hard = Engine::new(FlakyPrepare {
            inner: CpuBackend::new(CpuAlgo::Naive),
            hard: true,
        });
        let err = hard.warmup(8).expect_err("a broken optional op must surface");
        assert!(matches!(err, MatexpError::Backend(_)), "{err:?}");
    }
}
