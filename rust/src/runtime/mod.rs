//! Execution runtime: the typed kernel IR ([`KernelOp`]), the pluggable
//! [`Backend`] layer with its buffer-residency arena
//! ([`arena::BufferArena`]), and the generic plan-replaying [`Engine`].
//!
//! The paper's §3.2 host flow (find device → context → memory → compile →
//! launch → query) maps onto the [`Backend`] trait; three implementations
//! ship:
//!
//! * [`CpuBackend`] — pure Rust over [`crate::linalg`]; the default, runs
//!   everywhere with no artifacts.
//! * [`SimBackend`] — the analytic Tesla C2050 timing model; Tables 2–5
//!   reproduce without hardware.
//! * [`PjrtBackend`] *(cargo feature `xla`)* — AOT HLO-text artifacts
//!   (`make artifacts`) compiled once and executed via PJRT with
//!   device-resident buffers.

pub mod any;
pub mod arena;
pub mod artifacts;
pub mod backend;
pub mod cpu;
pub mod engine;
pub mod op;
pub mod sim;

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod literal;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use any::{AnyBackend, AnyBuffer};
pub use arena::{ArenaMat, BufferArena};
pub use artifacts::{ArtifactEntry, ArtifactRegistry};
pub use backend::{Backend, ResidencyStats, SplitPair, FUSED_EXPM_POWERS};
pub use cpu::{CpuBackend, CpuBuffer};
pub use engine::{AnyEngine, CpuEngine, DeviceStats, Engine, ExecStats, SimEngine};
pub use op::KernelOp;
pub use sim::SimBackend;

#[cfg(feature = "xla")]
pub use pjrt::PjrtBackend;

use crate::error::{MatexpError, Result};

/// Which execution backend to run on (config/CLI selectable).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Pure-Rust CPU execution — the default; runs everywhere.
    #[default]
    Cpu,
    /// Tesla C2050 analytic timing model (CPU numerics, simulated clock).
    Sim,
    /// AOT artifacts on PJRT (needs the `xla` cargo feature + artifacts).
    Pjrt,
    /// Heterogeneous multi-device pool ([`crate::pool`]): N cpu/sim
    /// devices behind a cost-model work splitter.
    Pool,
}

impl BackendKind {
    /// Canonical lowercase name (CLI/config vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Pool => "pool",
        }
    }

    /// Every backend kind, for exhaustive parsing/tests.
    pub fn all() -> [BackendKind; 4] {
        [BackendKind::Cpu, BackendKind::Sim, BackendKind::Pjrt, BackendKind::Pool]
    }
}

impl std::str::FromStr for BackendKind {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<Self> {
        BackendKind::all()
            .into_iter()
            .find(|k| k.as_str() == s.to_ascii_lowercase())
            .ok_or_else(|| {
                MatexpError::Config(format!("unknown backend {s:?} (cpu|sim|pjrt|pool)"))
            })
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which AOT kernel variant the PJRT backend executes (both are
/// numerically pytest-verified against the same oracle).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Plain `jnp.dot` lowering — the fast path on the CPU testbed.
    Xla,
    /// The Layer-1 tiled Pallas kernel (interpret-mode) — structural
    /// fidelity to the paper's §4.3 OpenCL kernel.
    Pallas,
}

impl Variant {
    /// Canonical lowercase name (CLI/config/manifest vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            Variant::Xla => "xla",
            Variant::Pallas => "pallas",
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "xla" => Ok(Variant::Xla),
            "pallas" => Ok(Variant::Pallas),
            other => Err(MatexpError::Config(format!("unknown variant {other:?}"))),
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn variant_parse_roundtrip() {
        for v in [Variant::Xla, Variant::Pallas] {
            assert_eq!(Variant::from_str(v.as_str()).unwrap(), v);
        }
        assert!(Variant::from_str("cuda").is_err());
        assert_eq!(Variant::from_str("XLA").unwrap(), Variant::Xla);
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        for k in BackendKind::all() {
            assert_eq!(BackendKind::from_str(k.as_str()).unwrap(), k);
        }
        assert!(BackendKind::from_str("tpu").is_err());
        assert_eq!(BackendKind::from_str("SIM").unwrap(), BackendKind::Sim);
        assert_eq!(BackendKind::default(), BackendKind::Cpu);
    }
}
