//! PJRT runtime: load AOT HLO-text artifacts, compile them once, execute
//! them from the coordinator hot path with device-resident buffers.
//!
//! This is the rust mirror of the OpenCL host API the paper describes in
//! §3.2 (find device → context → memory → compile → launch → query), with
//! the compile step moved to build time (`make artifacts`).

pub mod artifacts;
pub mod client;
pub mod engine;
pub mod literal;

pub use artifacts::{ArtifactEntry, ArtifactRegistry};
pub use engine::Engine;

use crate::error::{MatexpError, Result};

/// Which AOT kernel variant the engine executes (both are numerically
/// pytest-verified against the same oracle).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Plain `jnp.dot` lowering — the fast path on the CPU testbed.
    Xla,
    /// The Layer-1 tiled Pallas kernel (interpret-mode) — structural
    /// fidelity to the paper's §4.3 OpenCL kernel.
    Pallas,
}

impl Variant {
    pub fn as_str(self) -> &'static str {
        match self {
            Variant::Xla => "xla",
            Variant::Pallas => "pallas",
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "xla" => Ok(Variant::Xla),
            "pallas" => Ok(Variant::Pallas),
            other => Err(MatexpError::Config(format!("unknown variant {other:?}"))),
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn variant_parse_roundtrip() {
        for v in [Variant::Xla, Variant::Pallas] {
            assert_eq!(Variant::from_str(v.as_str()).unwrap(), v);
        }
        assert!(Variant::from_str("cuda").is_err());
        assert_eq!(Variant::from_str("XLA").unwrap(), Variant::Xla);
    }
}
