//! [`PjrtBackend`] — AOT HLO-text artifacts executed on a PJRT client
//! (the original runtime path, now behind the `xla` cargo feature).
//!
//! This is the rust mirror of the OpenCL host API the paper describes in
//! §3.2 (find device → context → memory → compile → launch → query), with
//! the compile step moved to build time (`make artifacts`). Executables
//! compile lazily on first use and are cached for the backend's lifetime.
//!
//! `PjrtBackend` is deliberately `!Send`: PJRT objects live on the thread
//! that created them. The coordinator gives each worker its own backend.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::runtime::artifacts::ArtifactRegistry;
use crate::runtime::backend::{Backend, SplitPair};
use crate::runtime::client;
use crate::runtime::literal::{download, literal_to_matrix, upload};
use crate::runtime::op::KernelOp;
use crate::runtime::Variant;

/// PJRT-executed backend over the artifact registry.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    variant: Variant,
    /// (op, n) → HLO path for this backend's variant (xla fallback for
    /// ops only lowered in the xla variant, e.g. `expm{N}`).
    info: HashMap<(String, usize), PathBuf>,
    /// Lazily compiled executables ((op, n) or ("entry:{name}", n)).
    exes: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    /// Build from a discovered registry.
    pub fn new(registry: &ArtifactRegistry, variant: Variant) -> Result<PjrtBackend> {
        let client = client::cpu_client()?;
        let mut info = HashMap::new();
        // xla entries first (fallback), then requested variant overrides
        for pass_variant in ["xla", variant.as_str()] {
            for e in registry.entries() {
                if e.variant == pass_variant && e.dtype == "f32" && e.tile.is_none() {
                    info.insert((e.op.clone(), e.n), registry.path(e));
                }
            }
        }
        Ok(PjrtBackend { client, variant, info, exes: HashMap::new() })
    }

    /// Which kernel variant's artifacts this backend executes.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    fn compile_path(client: &xla::PjRtClient, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| MatexpError::Artifact("non-utf8 path".into()))?,
        )?;
        Ok(client.compile(&xla::XlaComputation::from_proto(&proto))?)
    }

    /// Compile (or fetch from cache) the executable for `(op, n)`. Op
    /// names appear here only because the artifact manifest is the string
    /// edge — [`KernelOp::name`] renders them.
    fn exe(&mut self, op: KernelOp, n: usize) -> Result<&xla::PjRtLoadedExecutable> {
        op.validate()?;
        let key = (op.name(), n);
        if !self.exes.contains_key(&key) {
            let path = self.info.get(&key).ok_or_else(|| {
                // an op the artifact set doesn't ship is ignorable by
                // warmup's optional pass; real compile failures are not
                MatexpError::UnsupportedOp(format!(
                    "no artifact for op={op} n={n} (variant {}); run `make artifacts`",
                    self.variant
                ))
            })?;
            let exe = Self::compile_path(&self.client, path)?;
            self.exes.insert(key.clone(), exe);
        }
        Ok(&self.exes[&key])
    }

    /// Compile an arbitrary manifest entry by name (the tile-sweep
    /// ablation needs the tiled entries `find` hides). Returns the
    /// entry's matrix size.
    pub fn prepare_entry(&mut self, registry: &ArtifactRegistry, name: &str) -> Result<usize> {
        let entry = registry
            .entries()
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| MatexpError::Artifact(format!("no artifact named {name}")))?;
        let key = (format!("entry:{name}"), entry.n);
        if !self.exes.contains_key(&key) {
            let exe = Self::compile_path(&self.client, &registry.path(entry))?;
            self.exes.insert(key, exe);
        }
        Ok(entry.n)
    }

    /// One launch of a previously prepared manifest entry.
    pub fn launch_entry(
        &mut self,
        name: &str,
        n: usize,
        inputs: &[Rc<xla::PjRtBuffer>],
    ) -> Result<Rc<xla::PjRtBuffer>> {
        let key = (format!("entry:{name}"), n);
        let exe = self
            .exes
            .get(&key)
            .ok_or_else(|| MatexpError::Artifact(format!("entry {name} not prepared")))?;
        let mut out = exe.execute_b::<Rc<xla::PjRtBuffer>>(inputs)?;
        let mut row = out.pop().ok_or_else(|| MatexpError::Xla("no output".into()))?;
        let buf = row.pop().ok_or_else(|| MatexpError::Xla("empty output row".into()))?;
        Ok(Rc::new(buf))
    }
}

impl Backend for PjrtBackend {
    type Buffer = Rc<xla::PjRtBuffer>;

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        client::platform_summary(&self.client)
    }

    fn prepare(&mut self, op: KernelOp, n: usize) -> Result<()> {
        self.exe(op, n).map(|_| ())
    }

    fn upload(&mut self, m: Matrix) -> Result<Self::Buffer> {
        Ok(Rc::new(upload(&self.client, &m)?))
    }

    fn download(&mut self, buf: &Self::Buffer, n: usize) -> Result<Matrix> {
        download(buf.as_ref(), n)
    }

    fn launch(&mut self, op: KernelOp, n: usize, inputs: &[Self::Buffer]) -> Result<Self::Buffer> {
        let exe = self.exe(op, n)?;
        let mut out = exe.execute_b::<Rc<xla::PjRtBuffer>>(inputs)?;
        let mut row = out.pop().ok_or_else(|| MatexpError::Xla("no output".into()))?;
        let buf = row.pop().ok_or_else(|| MatexpError::Xla("empty output row".into()))?;
        Ok(Rc::new(buf))
    }

    /// PJRT hands back ONE tuple buffer for the 2-tuple `sqmul` artifact,
    /// so splitting costs a host round-trip — measured honestly (this is
    /// ablation A2's "bad" arm; the packed path avoids it).
    fn split_pair(&mut self, buf: Self::Buffer, n: usize) -> Result<SplitPair<Self::Buffer>> {
        let parts = buf.to_literal_sync()?.to_tuple()?;
        if parts.len() != 2 {
            return Err(MatexpError::Xla(format!("expected a 2-tuple, got {}-tuple", parts.len())));
        }
        let mut it = parts.into_iter();
        let first = literal_to_matrix(&it.next().unwrap(), n)?;
        let second = literal_to_matrix(&it.next().unwrap(), n)?;
        Ok(SplitPair {
            first: Rc::new(upload(&self.client, &first)?),
            second: Rc::new(upload(&self.client, &second)?),
            h2d_transfers: 2,
            d2h_transfers: 2,
        })
    }
}
