//! `Matrix` ⇄ XLA `Literal` / `PjRtBuffer` conversions — the explicit
//! host↔device memory management of the paper's §3.2.1, in rust.

use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;

/// Host matrix → host literal of shape `[n, n]`.
pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    let n = m.n() as i64;
    Ok(xla::Literal::vec1(m.data()).reshape(&[n, n])?)
}

/// Host literal of shape `[n, n]` → matrix.
pub fn literal_to_matrix(lit: &xla::Literal, n: usize) -> Result<Matrix> {
    let data = lit.to_vec::<f32>()?;
    Matrix::from_vec(n, data).map_err(|_| {
        MatexpError::Xla(format!(
            "literal has {} elements, expected {}x{}",
            lit.element_count(),
            n,
            n
        ))
    })
}

/// Host matrix → device buffer (one H2D transfer).
pub fn upload(client: &xla::PjRtClient, m: &Matrix) -> Result<xla::PjRtBuffer> {
    let n = m.n();
    Ok(client.buffer_from_host_buffer(m.data(), &[n, n], None)?)
}

/// Device buffer → host matrix (one D2H transfer).
pub fn download(buffer: &xla::PjRtBuffer, n: usize) -> Result<Matrix> {
    let lit = buffer.to_literal_sync()?;
    literal_to_matrix(&lit, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::client::cpu_client;

    // with the offline xla-stub every literal/buffer call errors; the
    // roundtrip assertions only run against a real PJRT link

    #[test]
    fn literal_roundtrip() {
        let m = Matrix::random(16, 5);
        let Ok(lit) = matrix_to_literal(&m) else {
            eprintln!("xla stub build; skipping");
            return;
        };
        let back = literal_to_matrix(&lit, 16).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn literal_size_mismatch_rejected() {
        let m = Matrix::random(4, 6);
        let Ok(lit) = matrix_to_literal(&m) else {
            eprintln!("xla stub build; skipping");
            return;
        };
        assert!(literal_to_matrix(&lit, 8).is_err());
    }

    #[test]
    fn buffer_roundtrip() {
        let Ok(client) = cpu_client() else {
            eprintln!("PJRT client unavailable (xla stub build?); skipping");
            return;
        };
        let m = Matrix::random(32, 7);
        let buf = upload(&client, &m).unwrap();
        let back = download(&buf, 32).unwrap();
        assert_eq!(m, back);
    }
}
