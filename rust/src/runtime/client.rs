//! PJRT client construction — the "find available target devices" step of
//! the OpenCL host flow (paper §3.2), reduced to the CPU plugin we have.

use crate::error::Result;

/// Create the PJRT CPU client.
///
/// On a real TPU/GPU deployment this is the only line that changes
/// (`PjRtClient::tpu(..)` / `::gpu(..)`); everything downstream works on
/// `PjRtBuffer`s and compiled executables and is device-agnostic.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}

/// Human-readable platform summary (for `matexp info`).
pub fn platform_summary(client: &xla::PjRtClient) -> String {
    format!(
        "{} ({} devices, version {})",
        client.platform_name(),
        client.device_count(),
        client.platform_version()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        // with the offline xla-stub the client constructor errors; only
        // assert against a real PJRT link
        let Ok(client) = cpu_client() else {
            eprintln!("PJRT client unavailable (xla stub build?); skipping");
            return;
        };
        assert!(client.device_count() >= 1);
        assert_eq!(client.platform_name(), "cpu");
        let s = platform_summary(&client);
        assert!(s.contains("cpu"), "{s}");
    }
}
