//! [`CpuBackend`] — the pure-Rust execution backend.
//!
//! Executes the launch vocabulary directly on the [`crate::linalg`]
//! substrate with a selectable matmul variant ([`CpuAlgo`]). This is the
//! default backend: it runs on any machine with no artifacts, no PJRT and
//! no GPU, which is what makes the test suite unconditional.
//!
//! "Device" buffers are host matrices behind `Rc`, so `Copy` steps and
//! register aliasing are pointer clones — the same cost shape as real
//! device-buffer aliasing — and the split of a packed pair is free
//! (reported as zero transfers, unlike PJRT's tuple round-trip).

use std::rc::Rc;

use crate::error::{MatexpError, Result};
use crate::linalg::expm::CpuAlgo;
use crate::linalg::matrix::Matrix;
use crate::linalg::MatmulFn;
use crate::plan::Plan;
use crate::runtime::backend::{Backend, SplitPair, FUSED_EXPM_POWERS};

/// A CPU "device" buffer: a single matrix or a packed `[acc, base]` pair.
#[derive(Clone, Debug)]
pub enum CpuBuffer {
    Mat(Rc<Matrix>),
    Pair(Rc<(Matrix, Matrix)>),
}

impl CpuBuffer {
    fn mat(&self) -> Result<&Matrix> {
        match self {
            CpuBuffer::Mat(m) => Ok(m.as_ref()),
            CpuBuffer::Pair(_) => {
                Err(MatexpError::Backend("expected a matrix buffer, got a packed pair".into()))
            }
        }
    }

    fn pair(&self) -> Result<&(Matrix, Matrix)> {
        match self {
            CpuBuffer::Pair(p) => Ok(p.as_ref()),
            CpuBuffer::Mat(_) => {
                Err(MatexpError::Backend("expected a packed pair buffer, got a matrix".into()))
            }
        }
    }
}

/// Pure-Rust backend over the `linalg` substrate.
pub struct CpuBackend {
    algo: CpuAlgo,
    matmul: MatmulFn,
}

impl CpuBackend {
    pub fn new(algo: CpuAlgo) -> CpuBackend {
        CpuBackend { algo, matmul: algo.matmul() }
    }

    pub fn algo(&self) -> CpuAlgo {
        self.algo
    }

    fn mm(&self, a: &Matrix, b: &Matrix) -> Matrix {
        (self.matmul)(a, b)
    }

    fn squares(&self, m: &Matrix, k: usize) -> Matrix {
        let mut acc = self.mm(m, m);
        for _ in 1..k {
            acc = self.mm(&acc, &acc);
        }
        acc
    }

    /// Validate an op name. Fused `expm{N}` availability mirrors the AOT
    /// artifact set ([`FUSED_EXPM_POWERS`]) so "is there a fused kernel
    /// for N?" answers the same on every backend.
    fn check_op(&self, op: &str) -> Result<()> {
        match op {
            "matmul" | "square" | "sqmul" | "pack2" | "step_sq" | "step_mul" | "unpack0" => Ok(()),
            _ => {
                if let Some(g) = op.strip_prefix("mma") {
                    let g: usize = g
                        .parse()
                        .map_err(|_| MatexpError::Backend(format!("unknown op {op:?}")))?;
                    if g < 1 {
                        return Err(MatexpError::Backend(format!("bad mma width {op:?}")));
                    }
                    return Ok(());
                }
                if let Some(k) = op.strip_prefix("square") {
                    let k: usize = k
                        .parse()
                        .map_err(|_| MatexpError::Backend(format!("unknown op {op:?}")))?;
                    if k < 2 {
                        return Err(MatexpError::Backend(format!("bad square chain {op:?}")));
                    }
                    return Ok(());
                }
                if let Some(power) = op.strip_prefix("expm") {
                    let power: u64 = power
                        .parse()
                        .map_err(|_| MatexpError::Backend(format!("unknown op {op:?}")))?;
                    if !FUSED_EXPM_POWERS.contains(&power) {
                        return Err(MatexpError::Artifact(format!(
                            "no artifact for op={op}: fused powers are {FUSED_EXPM_POWERS:?}"
                        )));
                    }
                    return Ok(());
                }
                Err(MatexpError::Backend(format!("unknown op {op:?}")))
            }
        }
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend::new(CpuAlgo::Blocked)
    }
}

fn arity_error(op: &str, want: usize, got: usize) -> MatexpError {
    MatexpError::Backend(format!("op {op:?} takes {want} inputs, got {got}"))
}

impl Backend for CpuBackend {
    type Buffer = CpuBuffer;

    fn name(&self) -> &'static str {
        "cpu"
    }

    fn platform(&self) -> String {
        format!("cpu backend (pure rust, matmul={})", self.algo.name())
    }

    fn prepare(&mut self, op: &str, _n: usize) -> Result<()> {
        self.check_op(op)
    }

    fn upload(&mut self, m: &Matrix) -> Result<CpuBuffer> {
        Ok(CpuBuffer::Mat(Rc::new(m.clone())))
    }

    fn download(&mut self, buf: &CpuBuffer, n: usize) -> Result<Matrix> {
        let m = buf.mat()?;
        if m.n() != n {
            return Err(MatexpError::Backend(format!(
                "buffer is {}x{}, expected {n}x{n}",
                m.n(),
                m.n()
            )));
        }
        Ok(m.clone())
    }

    fn launch(&mut self, op: &str, _n: usize, inputs: &[CpuBuffer]) -> Result<CpuBuffer> {
        let need = |want: usize| -> Result<()> {
            if inputs.len() != want {
                return Err(arity_error(op, want, inputs.len()));
            }
            Ok(())
        };
        match op {
            "matmul" => {
                need(2)?;
                let (a, b) = (inputs[0].mat()?, inputs[1].mat()?);
                if a.n() != b.n() {
                    return Err(MatexpError::Linalg("matmul size mismatch".into()));
                }
                Ok(CpuBuffer::Mat(Rc::new(self.mm(a, b))))
            }
            "square" => {
                need(1)?;
                let a = inputs[0].mat()?;
                Ok(CpuBuffer::Mat(Rc::new(self.mm(a, a))))
            }
            "sqmul" => {
                need(2)?;
                let (acc, base) = (inputs[0].mat()?, inputs[1].mat()?);
                Ok(CpuBuffer::Pair(Rc::new((self.mm(acc, base), self.mm(base, base)))))
            }
            "pack2" => {
                need(1)?;
                let b = inputs[0].mat()?;
                Ok(CpuBuffer::Pair(Rc::new((b.clone(), b.clone()))))
            }
            "step_sq" => {
                need(1)?;
                let (acc, base) = &*inputs[0].pair()?;
                Ok(CpuBuffer::Pair(Rc::new((acc.clone(), self.mm(base, base)))))
            }
            "step_mul" => {
                need(1)?;
                let (acc, base) = &*inputs[0].pair()?;
                let base2 = self.mm(base, base);
                let acc2 = self.mm(acc, &base2);
                Ok(CpuBuffer::Pair(Rc::new((acc2, base2))))
            }
            "unpack0" => {
                need(1)?;
                let (acc, _) = &*inputs[0].pair()?;
                Ok(CpuBuffer::Mat(Rc::new(acc.clone())))
            }
            _ => {
                self.check_op(op)?;
                if let Some(g) = op.strip_prefix("mma") {
                    let g: usize = g.parse().expect("checked by check_op");
                    need(2 * g)?;
                    let n = inputs[0].mat()?.n();
                    let mut acc = Matrix::zeros(n);
                    for k in 0..g {
                        let a = inputs[k].mat()?;
                        let b = inputs[g + k].mat()?;
                        if a.n() != n || b.n() != n {
                            return Err(MatexpError::Linalg("mma tile size mismatch".into()));
                        }
                        let prod = self.mm(a, b);
                        for (dst, src) in acc.data_mut().iter_mut().zip(prod.data()) {
                            *dst += *src;
                        }
                    }
                    return Ok(CpuBuffer::Mat(Rc::new(acc)));
                }
                if let Some(k) = op.strip_prefix("square") {
                    need(1)?;
                    let k: usize = k.parse().expect("checked by check_op");
                    return Ok(CpuBuffer::Mat(Rc::new(self.squares(inputs[0].mat()?, k))));
                }
                // check_op leaves only expm{N} with a shipped power
                let power: u64 =
                    op.strip_prefix("expm").expect("checked").parse().expect("checked");
                need(1)?;
                let a = inputs[0].mat()?.clone();
                let out = Plan::binary(power, false).eval(a, |x, y| self.mm(x, y))?;
                Ok(CpuBuffer::Mat(Rc::new(out)))
            }
        }
    }

    fn split_pair(&mut self, buf: &CpuBuffer, _n: usize) -> Result<SplitPair<CpuBuffer>> {
        let (first, second) = &*buf.pair()?;
        Ok(SplitPair {
            first: CpuBuffer::Mat(Rc::new(first.clone())),
            second: CpuBuffer::Mat(Rc::new(second.clone())),
            h2d_transfers: 0,
            d2h_transfers: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    fn backend() -> CpuBackend {
        CpuBackend::new(CpuAlgo::Naive)
    }

    fn up(b: &mut CpuBackend, m: &Matrix) -> CpuBuffer {
        b.upload(m).unwrap()
    }

    #[test]
    fn matmul_and_square_match_substrate() {
        let mut b = backend();
        let x = Matrix::random(8, 3);
        let y = Matrix::random(8, 4);
        let (bx, by) = (up(&mut b, &x), up(&mut b, &y));
        let got = b.launch("matmul", 8, &[bx.clone(), by]).unwrap();
        assert_eq!(b.download(&got, 8).unwrap(), matmul_naive(&x, &y));
        let sq = b.launch("square", 8, &[bx]).unwrap();
        assert_eq!(b.download(&sq, 8).unwrap(), matmul_naive(&x, &x));
    }

    #[test]
    fn packed_state_ops_implement_square_and_multiply() {
        let mut b = backend();
        let a = Matrix::random_spectral(6, 0.9, 9);
        // power 5 = 0b101: pack (acc=base=A), step_sq, step_mul, unpack
        let base = up(&mut b, &a);
        let mut state = b.launch("pack2", 6, &[base]).unwrap();
        state = b.launch("step_sq", 6, &[state]).unwrap();
        state = b.launch("step_mul", 6, &[state]).unwrap();
        let acc = b.launch("unpack0", 6, &[state]).unwrap();
        let got = b.download(&acc, 6).unwrap();
        let want = crate::linalg::expm::expm_naive(&a, 5, CpuAlgo::Naive).unwrap();
        assert!(got.approx_eq(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn sqmul_returns_product_and_square() {
        let mut b = backend();
        let acc = Matrix::random(5, 1);
        let base = Matrix::random(5, 2);
        let out = b
            .launch("sqmul", 5, &[up(&mut b, &acc), up(&mut b, &base)])
            .unwrap();
        let split = b.split_pair(&out, 5).unwrap();
        assert_eq!(split.h2d_transfers + split.d2h_transfers, 0, "cpu split is free");
        assert_eq!(b.download(&split.first, 5).unwrap(), matmul_naive(&acc, &base));
        assert_eq!(b.download(&split.second, 5).unwrap(), matmul_naive(&base, &base));
    }

    #[test]
    fn square_chain_is_repeated_squaring() {
        let mut b = backend();
        let a = Matrix::random_spectral(4, 0.9, 7);
        let out = b.launch("square4", 4, &[up(&mut b, &a)]).unwrap();
        let want = crate::linalg::expm::expm_naive(&a, 16, CpuAlgo::Naive).unwrap();
        assert!(b.download(&out, 4).unwrap().approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn fused_expm_mirrors_artifact_powers() {
        let mut b = backend();
        let a = Matrix::random_spectral(4, 0.9, 8);
        let buf = up(&mut b, &a);
        assert!(b.prepare("expm64", 4).is_ok());
        assert!(b.prepare("expm65", 4).is_err(), "non-shipped power must error");
        let out = b.launch("expm64", 4, &[buf]).unwrap();
        let want = crate::linalg::expm::expm(&a, 64, CpuAlgo::Naive).unwrap();
        assert!(b.download(&out, 4).unwrap().approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn mma_accumulates_tile_products() {
        let mut b = backend();
        let a1 = Matrix::random(6, 1);
        let a2 = Matrix::random(6, 2);
        let b1 = Matrix::random(6, 3);
        let b2 = Matrix::random(6, 4);
        let inputs = [up(&mut b, &a1), up(&mut b, &a2), up(&mut b, &b1), up(&mut b, &b2)];
        let out = b.launch("mma2", 6, &inputs).unwrap();
        let p1 = matmul_naive(&a1, &b1);
        let p2 = matmul_naive(&a2, &b2);
        let mut want = p1.clone();
        for (dst, src) in want.data_mut().iter_mut().zip(p2.data()) {
            *dst += *src;
        }
        let got = b.download(&out, 6).unwrap();
        assert!(got.approx_eq(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
        // mma1 degenerates to a plain matmul
        let one = b.launch("mma1", 6, &[up(&mut b, &a1), up(&mut b, &b1)]).unwrap();
        assert!(b.download(&one, 6).unwrap().approx_eq(&p1, 1e-4, 1e-4));
        // bad widths and arities rejected
        assert!(b.prepare("mma0", 6).is_err());
        assert!(b.prepare("mmaX", 6).is_err());
        assert!(b.launch("mma2", 6, &inputs[..3]).is_err(), "arity");
    }

    #[test]
    fn unknown_ops_and_bad_buffers_rejected() {
        let mut b = backend();
        assert!(b.prepare("conv2d", 8).is_err());
        let a = up(&mut b, &Matrix::identity(4));
        assert!(b.launch("unpack0", 4, &[a.clone()]).is_err(), "matrix is not a pair");
        assert!(b.launch("matmul", 4, &[a.clone()]).is_err(), "arity");
        assert!(b.split_pair(&a, 4).is_err());
        assert!(b.download(&a, 8).is_err(), "size mismatch surfaces");
    }
}
