//! [`CpuBackend`] — the pure-Rust execution backend.
//!
//! Executes the typed launch vocabulary ([`KernelOp`]) directly on the
//! [`crate::linalg`] substrate with a selectable matmul variant
//! ([`CpuAlgo`]). This is the default backend: it runs on any machine with
//! no artifacts, no PJRT and no GPU, which is what makes the test suite
//! unconditional.
//!
//! Data path: "device" buffers are host matrices behind `Rc`, owned by a
//! recycling [`BufferArena`]. `upload` adopts the caller's allocation
//! without copying, every launch writes into a recycled output buffer via
//! the in-place `matmul_*_into` kernels, and pack/unpack/split of the
//! packed `[acc, base]` pair are pure pointer aliasing — so a k-step
//! squaring chain performs exactly the two host-edge copies the paper's
//! model predicts, not O(k·n²) clones. The arena's [`ResidencyStats`]
//! report what the data path actually cost.

use std::rc::Rc;

use crate::error::{MatexpError, Result};
use crate::linalg::expm::CpuAlgo;
use crate::linalg::matrix::Matrix;
use crate::linalg::MatmulIntoFn;
use crate::plan::Plan;
use crate::runtime::arena::{ArenaMat, BufferArena};
use crate::runtime::backend::{Backend, ResidencyStats, SplitPair, FUSED_EXPM_POWERS};
use crate::runtime::op::KernelOp;

/// A CPU "device" buffer: a single matrix or a packed `[acc, base]` pair.
/// Pair halves are independent `Rc`s, so packing, unpacking and splitting
/// never copy matrix data.
#[derive(Clone, Debug)]
pub enum CpuBuffer {
    /// A single device-resident matrix.
    Mat(Rc<ArenaMat>),
    /// A packed `[acc, base]` pair (independent `Rc` halves).
    Pair(Rc<ArenaMat>, Rc<ArenaMat>),
}

impl CpuBuffer {
    fn mat(&self) -> Result<&Matrix> {
        match self {
            CpuBuffer::Mat(m) => Ok(m.matrix()),
            CpuBuffer::Pair(..) => {
                Err(MatexpError::Backend("expected a matrix buffer, got a packed pair".into()))
            }
        }
    }

    fn pair(&self) -> Result<(&Rc<ArenaMat>, &Rc<ArenaMat>)> {
        match self {
            CpuBuffer::Pair(acc, base) => Ok((acc, base)),
            CpuBuffer::Mat(_) => {
                Err(MatexpError::Backend("expected a packed pair buffer, got a matrix".into()))
            }
        }
    }
}

/// Pure-Rust backend over the `linalg` substrate.
pub struct CpuBackend {
    algo: CpuAlgo,
    matmul_into: MatmulIntoFn,
    arena: BufferArena,
}

impl CpuBackend {
    /// A backend executing launches with the given matmul variant.
    pub fn new(algo: CpuAlgo) -> CpuBackend {
        CpuBackend { algo, matmul_into: algo.matmul_into(), arena: BufferArena::new() }
    }

    /// The matmul variant this backend launches with.
    pub fn algo(&self) -> CpuAlgo {
        self.algo
    }

    /// `a · b` into a recycled arena buffer (the one place compute and the
    /// buffer layer meet).
    fn mm(&self, a: &Matrix, b: &Matrix) -> Result<ArenaMat> {
        if a.n() != b.n() {
            return Err(MatexpError::Linalg("matmul size mismatch".into()));
        }
        let mut out = self.arena.alloc(a.n());
        (self.matmul_into)(a, b, out.matrix_mut());
        Ok(out)
    }

    fn bytes(n: usize) -> u64 {
        (n * n * std::mem::size_of::<f32>()) as u64
    }

    /// Validate an op. Fused [`KernelOp::Expm`] availability mirrors the
    /// AOT artifact set ([`FUSED_EXPM_POWERS`]) so "is there a fused
    /// kernel for N?" answers the same on every backend; an absent power
    /// is [`MatexpError::UnsupportedOp`] (ignorable by warmup), while a
    /// degenerate parameter is a hard backend error.
    fn check_op(&self, op: KernelOp) -> Result<()> {
        op.validate()?;
        if let KernelOp::Expm(power) = op {
            if !FUSED_EXPM_POWERS.contains(&power) {
                return Err(MatexpError::UnsupportedOp(format!(
                    "no fused kernel for exponent {power}: shipped powers are {FUSED_EXPM_POWERS:?}"
                )));
            }
        }
        Ok(())
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend::new(CpuAlgo::Blocked)
    }
}

fn arity_error(op: KernelOp, want: usize, got: usize) -> MatexpError {
    MatexpError::Backend(format!("op {op} takes {want} inputs, got {got}"))
}

impl Backend for CpuBackend {
    type Buffer = CpuBuffer;

    fn name(&self) -> &'static str {
        "cpu"
    }

    fn platform(&self) -> String {
        format!("cpu backend (pure rust, matmul={})", self.algo.name())
    }

    fn prepare(&mut self, op: KernelOp, _n: usize) -> Result<()> {
        self.check_op(op)
    }

    fn upload(&mut self, m: Matrix) -> Result<CpuBuffer> {
        // one H2D edge crossing; the allocation itself is adopted, not
        // cloned — the caller's clone at the edge is the copy we charge
        self.arena.count_copied(Self::bytes(m.n()));
        Ok(CpuBuffer::Mat(Rc::new(self.arena.adopt(m))))
    }

    fn download(&mut self, buf: &CpuBuffer, n: usize) -> Result<Matrix> {
        let m = buf.mat()?;
        if m.n() != n {
            return Err(MatexpError::Backend(format!(
                "buffer is {}x{}, expected {n}x{n}",
                m.n(),
                m.n()
            )));
        }
        // one D2H edge crossing: the result leaves the arena as a copy
        self.arena.count_copied(Self::bytes(n));
        Ok(m.clone())
    }

    fn launch(&mut self, op: KernelOp, _n: usize, inputs: &[CpuBuffer]) -> Result<CpuBuffer> {
        self.check_op(op)?;
        if inputs.len() != op.arity() {
            return Err(arity_error(op, op.arity(), inputs.len()));
        }
        match op {
            KernelOp::Matmul => {
                let (a, b) = (inputs[0].mat()?, inputs[1].mat()?);
                Ok(CpuBuffer::Mat(Rc::new(self.mm(a, b)?)))
            }
            KernelOp::Square => {
                let a = inputs[0].mat()?;
                Ok(CpuBuffer::Mat(Rc::new(self.mm(a, a)?)))
            }
            KernelOp::SqMul => {
                let (acc, base) = (inputs[0].mat()?, inputs[1].mat()?);
                let prod = self.mm(acc, base)?;
                let sq = self.mm(base, base)?;
                Ok(CpuBuffer::Pair(Rc::new(prod), Rc::new(sq)))
            }
            KernelOp::Pack2 => {
                // acc and base alias the same device data: zero copies
                let CpuBuffer::Mat(rc) = &inputs[0] else {
                    return Err(MatexpError::Backend(
                        "expected a matrix buffer, got a packed pair".into(),
                    ));
                };
                Ok(CpuBuffer::Pair(Rc::clone(rc), Rc::clone(rc)))
            }
            KernelOp::StepSq => {
                let (acc, base) = inputs[0].pair()?;
                let sq = self.mm(base.matrix(), base.matrix())?;
                Ok(CpuBuffer::Pair(Rc::clone(acc), Rc::new(sq)))
            }
            KernelOp::StepMul => {
                let (acc, base) = inputs[0].pair()?;
                let base2 = self.mm(base.matrix(), base.matrix())?;
                let acc2 = self.mm(acc.matrix(), base2.matrix())?;
                Ok(CpuBuffer::Pair(Rc::new(acc2), Rc::new(base2)))
            }
            KernelOp::Unpack0 => {
                let (acc, _) = inputs[0].pair()?;
                Ok(CpuBuffer::Mat(Rc::clone(acc)))
            }
            KernelOp::Mma(g) => {
                let g = g as usize;
                let n = inputs[0].mat()?.n();
                let mut acc = self.mm(inputs[0].mat()?, inputs[g].mat()?)?;
                for k in 1..g {
                    let a = inputs[k].mat()?;
                    let b = inputs[g + k].mat()?;
                    if a.n() != n || b.n() != n {
                        return Err(MatexpError::Linalg("mma tile size mismatch".into()));
                    }
                    let prod = self.mm(a, b)?; // recycles between iterations
                    for (dst, src) in acc.matrix_mut().data_mut().iter_mut().zip(prod.data()) {
                        *dst += *src;
                    }
                }
                Ok(CpuBuffer::Mat(Rc::new(acc)))
            }
            KernelOp::SquareChain(k) => {
                let mut cur = self.mm(inputs[0].mat()?, inputs[0].mat()?)?;
                for _ in 1..k {
                    // the previous buffer drops right back into the arena
                    cur = self.mm(cur.matrix(), cur.matrix())?;
                }
                Ok(CpuBuffer::Mat(Rc::new(cur)))
            }
            KernelOp::Expm(power) => {
                // modeled as ONE fused device kernel: internal temporaries
                // are device-internal, only the result joins the arena
                let a = inputs[0].mat()?.clone();
                let n = a.n();
                let f = self.matmul_into;
                let out = Plan::binary(power, false).eval(a, |x, y| {
                    let mut c = Matrix::zeros(n);
                    f(x, y, &mut c);
                    c
                })?;
                Ok(CpuBuffer::Mat(Rc::new(self.arena.adopt(out))))
            }
        }
    }

    fn split_pair(&mut self, buf: CpuBuffer, _n: usize) -> Result<SplitPair<CpuBuffer>> {
        let (acc, base) = buf.pair()?;
        Ok(SplitPair {
            first: CpuBuffer::Mat(Rc::clone(acc)),
            second: CpuBuffer::Mat(Rc::clone(base)),
            h2d_transfers: 0,
            d2h_transfers: 0,
        })
    }

    fn take_residency(&mut self) -> ResidencyStats {
        self.arena.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    fn backend() -> CpuBackend {
        CpuBackend::new(CpuAlgo::Naive)
    }

    fn up(b: &mut CpuBackend, m: &Matrix) -> CpuBuffer {
        b.upload(m.clone()).unwrap()
    }

    #[test]
    fn matmul_and_square_match_substrate() {
        let mut b = backend();
        let x = Matrix::random(8, 3);
        let y = Matrix::random(8, 4);
        let (bx, by) = (up(&mut b, &x), up(&mut b, &y));
        let got = b.launch(KernelOp::Matmul, 8, &[bx.clone(), by]).unwrap();
        assert_eq!(b.download(&got, 8).unwrap(), matmul_naive(&x, &y));
        let sq = b.launch(KernelOp::Square, 8, &[bx]).unwrap();
        assert_eq!(b.download(&sq, 8).unwrap(), matmul_naive(&x, &x));
    }

    #[test]
    fn packed_state_ops_implement_square_and_multiply() {
        let mut b = backend();
        let a = Matrix::random_spectral(6, 0.9, 9);
        // power 5 = 0b101: pack (acc=base=A), step_sq, step_mul, unpack
        let base = up(&mut b, &a);
        let mut state = b.launch(KernelOp::Pack2, 6, &[base]).unwrap();
        state = b.launch(KernelOp::StepSq, 6, &[state]).unwrap();
        state = b.launch(KernelOp::StepMul, 6, &[state]).unwrap();
        let acc = b.launch(KernelOp::Unpack0, 6, &[state]).unwrap();
        let got = b.download(&acc, 6).unwrap();
        let want = crate::linalg::expm::expm_naive(&a, 5, CpuAlgo::Naive).unwrap();
        assert!(got.approx_eq(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn sqmul_returns_product_and_square() {
        let mut b = backend();
        let acc = Matrix::random(5, 1);
        let base = Matrix::random(5, 2);
        let out = b
            .launch(KernelOp::SqMul, 5, &[up(&mut b, &acc), up(&mut b, &base)])
            .unwrap();
        let split = b.split_pair(out, 5).unwrap();
        assert_eq!(split.h2d_transfers + split.d2h_transfers, 0, "cpu split is free");
        assert_eq!(b.download(&split.first, 5).unwrap(), matmul_naive(&acc, &base));
        assert_eq!(b.download(&split.second, 5).unwrap(), matmul_naive(&base, &base));
    }

    #[test]
    fn square_chain_is_repeated_squaring() {
        let mut b = backend();
        let a = Matrix::random_spectral(4, 0.9, 7);
        let out = b.launch(KernelOp::SquareChain(4), 4, &[up(&mut b, &a)]).unwrap();
        let want = crate::linalg::expm::expm_naive(&a, 16, CpuAlgo::Naive).unwrap();
        assert!(b.download(&out, 4).unwrap().approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn fused_expm_mirrors_artifact_powers() {
        let mut b = backend();
        let a = Matrix::random_spectral(4, 0.9, 8);
        let buf = up(&mut b, &a);
        assert!(b.prepare(KernelOp::Expm(64), 4).is_ok());
        // a non-shipped power is an UnsupportedOp, not a hard failure
        assert!(matches!(
            b.prepare(KernelOp::Expm(65), 4),
            Err(MatexpError::UnsupportedOp(_))
        ));
        let out = b.launch(KernelOp::Expm(64), 4, &[buf]).unwrap();
        let want = crate::linalg::expm::expm(&a, 64, CpuAlgo::Naive).unwrap();
        assert!(b.download(&out, 4).unwrap().approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn mma_accumulates_tile_products() {
        let mut b = backend();
        let a1 = Matrix::random(6, 1);
        let a2 = Matrix::random(6, 2);
        let b1 = Matrix::random(6, 3);
        let b2 = Matrix::random(6, 4);
        let inputs = [up(&mut b, &a1), up(&mut b, &a2), up(&mut b, &b1), up(&mut b, &b2)];
        let out = b.launch(KernelOp::Mma(2), 6, &inputs).unwrap();
        let p1 = matmul_naive(&a1, &b1);
        let p2 = matmul_naive(&a2, &b2);
        let mut want = p1.clone();
        for (dst, src) in want.data_mut().iter_mut().zip(p2.data()) {
            *dst += *src;
        }
        let got = b.download(&out, 6).unwrap();
        assert!(got.approx_eq(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
        // mma width 1 degenerates to a plain matmul
        let one = b.launch(KernelOp::Mma(1), 6, &[up(&mut b, &a1), up(&mut b, &b1)]).unwrap();
        assert!(b.download(&one, 6).unwrap().approx_eq(&p1, 1e-4, 1e-4));
        // bad widths and arities rejected
        assert!(b.prepare(KernelOp::Mma(0), 6).is_err());
        assert!(b.launch(KernelOp::Mma(2), 6, &inputs[..3]).is_err(), "arity");
    }

    #[test]
    fn bad_buffers_rejected() {
        let mut b = backend();
        let a = up(&mut b, &Matrix::identity(4));
        assert!(b.launch(KernelOp::Unpack0, 4, &[a.clone()]).is_err(), "matrix is not a pair");
        assert!(b.launch(KernelOp::Matmul, 4, &[a.clone()]).is_err(), "arity");
        assert!(b.split_pair(a.clone(), 4).is_err());
        assert!(b.download(&a, 8).is_err(), "size mismatch surfaces");
    }

    #[test]
    fn data_path_copies_only_the_host_edges() {
        let mut b = backend();
        let a = Matrix::random_spectral(8, 0.9, 11);
        let _ = b.take_residency(); // reset
        let mut buf = b.upload(a).unwrap();
        // a 6-launch squaring chain: every output lands in an arena buffer
        for _ in 0..6 {
            buf = b.launch(KernelOp::Square, 8, &[buf]).unwrap();
        }
        let _ = b.download(&buf, 8).unwrap();
        let r = b.take_residency();
        assert_eq!(r.bytes_copied, 2 * 8 * 8 * 4, "one upload + one download");
        // launch 1 allocates fresh; the engine-style ping-pong recycles
        // from launch 3 on (launch 2's input is still held by `buf`)
        assert!(r.buffers_recycled >= 4, "{r:?}");
    }

    #[test]
    fn pack_unpack_and_split_are_zero_copy() {
        let mut b = backend();
        let a = Matrix::random(16, 5);
        let buf = b.upload(a).unwrap();
        let _ = b.take_residency();
        let pair = b.launch(KernelOp::Pack2, 16, &[buf]).unwrap();
        let split = b.split_pair(pair.clone(), 16).unwrap();
        let _ = b.launch(KernelOp::Unpack0, 16, &[pair]).unwrap();
        drop(split);
        let r = b.take_residency();
        assert_eq!(r.bytes_copied, 0, "aliasing, not copying");
    }
}
