//! [`SimBackend`] — the Tesla C2050 timing-model backend.
//!
//! Numerics run on an inner [`CpuBackend`] (results are real matrices, so
//! correctness tests pass), while wall-clock is *simulated*: every upload,
//! download, launch and pair-split advances an analytic clock built from
//! the [`GpuTimingModel`] (launch overhead + PCIe transfer + roofline
//! kernel time). The engine reads the clock through
//! [`Backend::take_sim_time`], so `ExecStats::wall_s` for a sim-backed
//! engine is the *predicted 2012-testbed time* — which is how Tables 2–5
//! reproduce on a machine with no GPU (repro band 0/5, DESIGN.md §6).

use crate::error::Result;
use crate::linalg::expm::CpuAlgo;
use crate::linalg::matrix::Matrix;
use crate::runtime::backend::{Backend, ResidencyStats, SplitPair};
use crate::runtime::cpu::{CpuBackend, CpuBuffer};
use crate::runtime::op::KernelOp;
use crate::simulator::device::DeviceSpec;
use crate::simulator::timing::GpuTimingModel;

/// Timing-model backend: CPU numerics, simulated clock.
pub struct SimBackend {
    inner: CpuBackend,
    model: GpuTimingModel,
    clock_s: f64,
    /// Edge bytes the *model* charges beyond what the CPU substrate
    /// physically copies (the pair-split tuple round-trip).
    modeled_copied: u64,
}

impl SimBackend {
    /// Simulate `model`; numerics via the blocked CPU matmul.
    pub fn new(model: GpuTimingModel) -> SimBackend {
        SimBackend {
            inner: CpuBackend::new(CpuAlgo::Blocked),
            model,
            clock_s: 0.0,
            modeled_copied: 0,
        }
    }

    /// Uncalibrated spec-sheet Tesla C2050 (the paper's device). The
    /// experiment harness swaps in the paper-calibrated model.
    pub fn tesla_c2050() -> SimBackend {
        SimBackend::new(GpuTimingModel::from_spec(DeviceSpec::tesla_c2050()))
    }

    /// The timing model this backend advances its clock with.
    pub fn model(&self) -> &GpuTimingModel {
        &self.model
    }

    /// Simulated seconds accumulated so far (without resetting).
    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }
}

impl Backend for SimBackend {
    type Buffer = CpuBuffer;

    fn name(&self) -> &'static str {
        "sim"
    }

    fn platform(&self) -> String {
        format!("simulated {} (analytic timing model, cpu numerics)", self.model.device.name)
    }

    fn prepare(&mut self, op: KernelOp, n: usize) -> Result<()> {
        // compilation is build-time on the modeled device: zero sim cost
        self.inner.prepare(op, n)
    }

    fn upload(&mut self, m: Matrix) -> Result<CpuBuffer> {
        self.clock_s += self.model.transfer_time(m.n(), 1);
        self.inner.upload(m)
    }

    fn download(&mut self, buf: &CpuBuffer, n: usize) -> Result<Matrix> {
        self.clock_s += self.model.transfer_time(n, 1);
        self.inner.download(buf, n)
    }

    fn launch(&mut self, op: KernelOp, n: usize, inputs: &[CpuBuffer]) -> Result<CpuBuffer> {
        let multiplies = op.multiplies();
        self.clock_s += self.model.eff_launch_overhead(n);
        if multiplies > 0 {
            self.clock_s += self.model.kernel_time(n, multiplies);
        }
        self.inner.launch(op, n, inputs)
    }

    fn split_pair(&mut self, buf: CpuBuffer, n: usize) -> Result<SplitPair<CpuBuffer>> {
        // the modeled device, like PJRT, splits a 2-tuple through the
        // host: 2 D2H + 2 H2D
        self.clock_s += self.model.transfer_time(n, 4);
        self.modeled_copied += 4 * (n * n * std::mem::size_of::<f32>()) as u64;
        let mut split = self.inner.split_pair(buf, n)?;
        split.d2h_transfers = 2;
        split.h2d_transfers = 2;
        Ok(split)
    }

    fn take_sim_time(&mut self) -> Option<f64> {
        let t = self.clock_s;
        self.clock_s = 0.0;
        Some(t)
    }

    fn models_time(&self) -> bool {
        true
    }

    fn take_residency(&mut self) -> ResidencyStats {
        let mut stats = self.inner.take_residency();
        stats.bytes_copied += std::mem::take(&mut self.modeled_copied);
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_on_transfers_and_launches() {
        let mut b = SimBackend::tesla_c2050();
        let a = Matrix::random_spectral(64, 0.9, 1);
        let buf = b.upload(a).unwrap();
        let after_upload = b.clock_s();
        assert!(after_upload > 0.0);
        let out = b.launch(KernelOp::Square, 64, &[buf]).unwrap();
        assert!(b.clock_s() > after_upload + b.model().launch_overhead_s * 0.9);
        let m = b.download(&out, 64).unwrap();
        assert!(m.is_finite());
        // take resets
        assert!(b.take_sim_time().unwrap() > 0.0);
        assert_eq!(b.take_sim_time().unwrap(), 0.0);
    }

    #[test]
    fn numerics_match_cpu_substrate() {
        let mut b = SimBackend::tesla_c2050();
        let a = Matrix::random_spectral(8, 0.9, 2);
        let buf = b.upload(a.clone()).unwrap();
        let out = b.launch(KernelOp::Square, 8, &[buf]).unwrap();
        let want = crate::linalg::naive::matmul_naive(&a, &a);
        assert!(b.download(&out, 8).unwrap().approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn split_charges_the_tuple_roundtrip() {
        let mut b = SimBackend::tesla_c2050();
        let a = b.upload(Matrix::identity(16)).unwrap();
        let pair = b.launch(KernelOp::Pack2, 16, &[a]).unwrap();
        let before = b.clock_s();
        let _ = b.take_residency();
        let split = b.split_pair(pair, 16).unwrap();
        assert_eq!((split.h2d_transfers, split.d2h_transfers), (2, 2));
        assert!(b.clock_s() > before);
        // the modeled tuple round-trip shows up in bytes_copied even
        // though the CPU substrate splits by aliasing
        assert_eq!(b.take_residency().bytes_copied, 4 * 16 * 16 * 4);
    }
}
