//! [`BufferArena`] — pooled `n×n` allocations plus the residency
//! accounting behind [`super::backend::ResidencyStats`].
//!
//! The paper's §4.3 speedup is a *data-path* claim: operands stay
//! device-resident, intermediates never round-trip, and a k-step squaring
//! chain touches the host exactly twice. The arena is the host-side
//! realization of that discipline for the pure-Rust backends:
//!
//! * [`BufferArena::adopt`] takes ownership of an uploaded matrix without
//!   copying it;
//! * [`BufferArena::alloc`] hands out an output buffer, reusing the
//!   allocation of any same-sized buffer that was dropped earlier — plan
//!   replay ping-pongs two resident buffers instead of allocating (and
//!   faulting in) a fresh `n×n` block per step;
//! * dropping the last [`std::rc::Rc`] clone of an [`ArenaMat`] returns
//!   its allocation to the free list automatically.
//!
//! Every host↔device edge crossing is charged to `bytes_copied`; arena
//! hits increment `buffers_recycled`; `peak_resident_bytes` tracks the
//! high-water mark of live (in-use) buffer bytes.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::{Rc, Weak};

use crate::linalg::matrix::Matrix;
use crate::runtime::backend::ResidencyStats;

/// Free buffers kept per element-count bucket; beyond this, dropped
/// allocations are released to the OS (bounds arena growth under mixed
/// sizes).
const FREE_PER_SIZE_CAP: usize = 8;

#[derive(Default)]
struct ArenaInner {
    /// Element count → reusable allocations (contents stale).
    free: HashMap<usize, Vec<Vec<f32>>>,
    /// Bytes currently held by live [`ArenaMat`]s.
    live_bytes: u64,
    /// High-water mark of `live_bytes` since the last [`BufferArena::take`].
    peak_bytes: u64,
    /// Host-edge bytes charged since the last take.
    bytes_copied: u64,
    /// Allocation requests served from the free list since the last take.
    recycled: u64,
}

/// Recycling allocator for square matrix buffers (one per backend).
#[derive(Default)]
pub struct BufferArena {
    inner: Rc<RefCell<ArenaInner>>,
}

impl BufferArena {
    /// An empty arena (no pooled allocations yet).
    pub fn new() -> BufferArena {
        BufferArena::default()
    }

    fn on_live(inner: &mut ArenaInner, bytes: u64) {
        inner.live_bytes += bytes;
        inner.peak_bytes = inner.peak_bytes.max(inner.live_bytes);
    }

    /// Take ownership of an existing matrix with **zero copy** (the
    /// caller's clone at the host edge — if any — is charged separately
    /// via [`BufferArena::count_copied`]).
    pub fn adopt(&self, m: Matrix) -> ArenaMat {
        let bytes = (m.data().len() * std::mem::size_of::<f32>()) as u64;
        Self::on_live(&mut self.inner.borrow_mut(), bytes);
        ArenaMat { mat: Some(m), arena: Rc::downgrade(&self.inner) }
    }

    /// An `n×n` output buffer with **unspecified contents** — recycled
    /// from the free list when possible, freshly allocated otherwise.
    /// Callers must fully overwrite it (every `matmul_*_into` kernel
    /// does).
    pub fn alloc(&self, n: usize) -> ArenaMat {
        let len = n * n;
        let bytes = (len * std::mem::size_of::<f32>()) as u64;
        let reused = {
            let mut inner = self.inner.borrow_mut();
            let reused = inner.free.get_mut(&len).and_then(Vec::pop);
            if reused.is_some() {
                inner.recycled += 1;
            }
            Self::on_live(&mut inner, bytes);
            reused
        };
        let data = reused.unwrap_or_else(|| vec![0.0; len]);
        let mat = Matrix::from_vec(n, data).expect("arena buckets are keyed by exact length");
        ArenaMat { mat: Some(mat), arena: Rc::downgrade(&self.inner) }
    }

    /// Charge one host↔device edge crossing of `bytes`.
    pub fn count_copied(&self, bytes: u64) {
        self.inner.borrow_mut().bytes_copied += bytes;
    }

    /// Drain the counters accumulated since the last take; the resident
    /// high-water mark restarts from the currently live bytes.
    pub fn take(&self) -> ResidencyStats {
        let mut inner = self.inner.borrow_mut();
        let stats = ResidencyStats {
            bytes_copied: inner.bytes_copied,
            buffers_recycled: inner.recycled,
            peak_resident_bytes: inner.peak_bytes,
        };
        inner.bytes_copied = 0;
        inner.recycled = 0;
        inner.peak_bytes = inner.live_bytes;
        stats
    }

    /// Free buffers currently pooled (tests/diagnostics).
    pub fn free_buffers(&self) -> usize {
        self.inner.borrow().free.values().map(Vec::len).sum()
    }
}

/// A matrix whose allocation returns to its [`BufferArena`] on drop.
/// Backends share these behind `Rc`; the allocation recycles when the
/// last clone drops.
#[derive(Debug)]
pub struct ArenaMat {
    mat: Option<Matrix>,
    arena: Weak<RefCell<ArenaInner>>,
}

impl ArenaMat {
    /// The held matrix.
    pub fn matrix(&self) -> &Matrix {
        self.mat.as_ref().expect("present until drop")
    }

    /// Mutable access to the held matrix (launch kernels write here).
    pub fn matrix_mut(&mut self) -> &mut Matrix {
        self.mat.as_mut().expect("present until drop")
    }

    /// Take the matrix out, detaching it from the arena: the allocation
    /// leaves with the caller instead of returning to the free list, and
    /// its bytes stop counting as live. The wire edge uses this to decode
    /// a request payload into a recycled buffer and then hand the engine
    /// an owned [`Matrix`].
    pub fn into_matrix(mut self) -> Matrix {
        let m = self.mat.take().expect("present until drop");
        if let Some(inner) = self.arena.upgrade() {
            let bytes = (m.data().len() * std::mem::size_of::<f32>()) as u64;
            let mut inner = inner.borrow_mut();
            inner.live_bytes = inner.live_bytes.saturating_sub(bytes);
        }
        m
    }
}

impl std::ops::Deref for ArenaMat {
    type Target = Matrix;

    fn deref(&self) -> &Matrix {
        self.matrix()
    }
}

impl Drop for ArenaMat {
    fn drop(&mut self) {
        let Some(m) = self.mat.take() else { return };
        let Some(inner) = self.arena.upgrade() else { return };
        let mut inner = inner.borrow_mut();
        let data = m.into_vec();
        inner.live_bytes =
            inner.live_bytes.saturating_sub((data.len() * std::mem::size_of::<f32>()) as u64);
        let bucket = inner.free.entry(data.len()).or_default();
        if bucket.len() < FREE_PER_SIZE_CAP {
            bucket.push(data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropped_buffers_recycle() {
        let arena = BufferArena::new();
        let a = arena.alloc(8);
        drop(a);
        assert_eq!(arena.free_buffers(), 1);
        let _b = arena.alloc(8); // served from the free list
        let stats = arena.take();
        assert_eq!(stats.buffers_recycled, 1);
        assert_eq!(arena.free_buffers(), 0);
    }

    #[test]
    fn ping_pong_reuses_two_allocations() {
        let arena = BufferArena::new();
        let mut cur = Rc::new(arena.alloc(16));
        for _ in 0..10 {
            let next = Rc::new(arena.alloc(16));
            cur = next; // previous buffer drops → recycles next round
        }
        drop(cur);
        let stats = arena.take();
        // first two allocs are fresh, the other 9 recycle
        assert_eq!(stats.buffers_recycled, 9);
        // never more than two 16×16 buffers live at once
        assert_eq!(stats.peak_resident_bytes, 2 * 16 * 16 * 4);
    }

    #[test]
    fn adopt_is_zero_copy_and_counts_nothing() {
        let arena = BufferArena::new();
        let m = Matrix::random(4, 1);
        let want = m.clone();
        let held = arena.adopt(m);
        assert_eq!(*held.matrix(), want);
        let stats = arena.take();
        assert_eq!(stats.bytes_copied, 0);
        assert_eq!(stats.peak_resident_bytes, 4 * 4 * 4);
    }

    #[test]
    fn copied_bytes_accumulate_and_reset() {
        let arena = BufferArena::new();
        arena.count_copied(100);
        arena.count_copied(24);
        assert_eq!(arena.take().bytes_copied, 124);
        assert_eq!(arena.take().bytes_copied, 0);
    }

    #[test]
    fn free_list_is_bounded() {
        let arena = BufferArena::new();
        let held: Vec<ArenaMat> = (0..20).map(|_| arena.alloc(4)).collect();
        drop(held);
        assert!(arena.free_buffers() <= FREE_PER_SIZE_CAP);
    }

    #[test]
    fn into_matrix_detaches_without_recycling() {
        let arena = BufferArena::new();
        drop(arena.alloc(4)); // seed the free list
        let mut held = arena.alloc(4); // recycled allocation
        held.matrix_mut().set(0, 0, 7.0);
        let m = held.into_matrix();
        assert_eq!(m.get(0, 0), 7.0);
        // the allocation left with the caller: nothing back on the free
        // list, nothing still counted live
        assert_eq!(arena.free_buffers(), 0);
        let stats = arena.take();
        assert_eq!(stats.buffers_recycled, 1);
        drop(m);
        assert_eq!(arena.free_buffers(), 0);
    }

    #[test]
    fn outliving_the_arena_is_safe() {
        let arena = BufferArena::new();
        let m = arena.alloc(4);
        drop(arena);
        drop(m); // weak upgrade fails; allocation just frees
    }

    #[test]
    fn alloc_shapes_are_exact() {
        let arena = BufferArena::new();
        drop(arena.alloc(8));
        // a 64-element free buffer must not serve an n=4 (16-element) ask
        let m = arena.alloc(4);
        assert_eq!(m.n(), 4);
        assert_eq!(arena.take().buffers_recycled, 0);
    }
}
