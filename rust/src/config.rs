//! Configuration system: JSON config file + env + CLI overrides.
//!
//! Precedence (lowest to highest): defaults → config file → environment
//! (`MATEXP_ARTIFACTS`) → CLI flags (applied by `main.rs`).

use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::error::{MatexpError, Result};
use crate::json_obj;
use crate::linalg::expm::CpuAlgo;
use crate::pool::PoolDeviceKind;
use crate::runtime::{BackendKind, Variant};
use crate::util::json::Json;

/// Dynamic batcher knobs (coordinator layer).
#[derive(Clone, Debug, PartialEq)]
pub struct BatcherConfig {
    /// Max requests coalesced into one batch.
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates, milliseconds.
    pub max_wait_ms: u64,
    /// Max queued requests before admission control rejects (backpressure).
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_wait_ms: 2, max_queue: 4096 }
    }
}

/// Device-pool knobs (the `pool` backend; see [`crate::pool`]).
#[derive(Clone, Debug, PartialEq)]
pub struct PoolConfig {
    /// The devices the pool owns, in order (`cpu` and/or `sim` entries).
    pub devices: Vec<PoolDeviceKind>,
    /// Below this matrix size a request runs whole on one device
    /// (request-parallel dispatch); at/above it, single large requests are
    /// tile-sharded across the pool.
    pub shard_min_n: usize,
    /// Force the tile grid to `g`×`g` instead of letting the cost model
    /// pick (tests and ablations; `None` = cost model decides).
    pub grid: Option<usize>,
    /// Largest grid dimension the cost model may consider.
    pub max_grid: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            devices: vec![PoolDeviceKind::Sim, PoolDeviceKind::Sim],
            shard_min_n: 512,
            grid: None,
            max_grid: 4,
        }
    }
}

/// Multi-tier cache knobs (see [`crate::cache`]).
///
/// Plan caching is semantically invisible (plans are pure functions of
/// their key) and defaults **on**; result caching changes what a response
/// *reports* (a warm hit performs zero launches), so it defaults **off**
/// and is enabled per deployment (`--cache-results`).
///
/// ```
/// use matexp::prelude::*;
///
/// let mut cfg = MatexpConfig::default();
/// assert!(cfg.cache.plans && !cfg.cache.results);
/// cfg.cache.results = true; // what `--cache-results` does
/// cfg.cache.budget_mb = 64; // what `--cache-budget-mb 64` does
/// assert_eq!(cfg.cache.budget_bytes(), 64 << 20);
/// cfg.validate().unwrap();
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheSettings {
    /// Memoize built launch plans ([`crate::cache::PlanCache`]).
    pub plans: bool,
    /// Serve repeated identical requests from the content-addressed
    /// result cache ([`crate::cache::ResultCache`]).
    pub results: bool,
    /// Byte budget of the result cache, mebibytes (LRU eviction).
    pub budget_mb: usize,
}

impl Default for CacheSettings {
    fn default() -> Self {
        Self { plans: true, results: false, budget_mb: 256 }
    }
}

impl CacheSettings {
    /// The result-cache budget in bytes.
    pub fn budget_bytes(&self) -> u64 {
        (self.budget_mb as u64) << 20
    }
}

/// Persistent artifact-store knobs (see [`crate::store`]).
///
/// Off by default (`dir: None`): no persistence, the result cache evicts
/// instead of spilling, and the store counters stay at zero. Setting a
/// directory (`--store-dir`) turns the tier on: results, the autotune
/// table and memoized plans persist there, survive restarts, and memory
/// evictions demote to disk instead of deleting work.
///
/// ```
/// use matexp::prelude::*;
///
/// let mut cfg = MatexpConfig::default();
/// assert!(cfg.store.dir.is_none(), "persistence is opt-in");
/// cfg.store.dir = Some("/tmp/matexp-store".into()); // what `--store-dir` does
/// cfg.store.budget_mb = 512; // what `--store-budget-mb 512` does
/// assert_eq!(cfg.store.budget_bytes(), 512 << 20);
/// cfg.validate().unwrap();
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreSettings {
    /// Directory for the on-disk artifact store; `None` disables
    /// persistence entirely.
    pub dir: Option<PathBuf>,
    /// Byte budget of the on-disk store, mebibytes (oldest entries are
    /// deleted first when a write would exceed it).
    pub budget_mb: usize,
}

impl Default for StoreSettings {
    fn default() -> Self {
        Self { dir: None, budget_mb: 1024 }
    }
}

impl StoreSettings {
    /// The on-disk budget in bytes.
    pub fn budget_bytes(&self) -> u64 {
        (self.budget_mb as u64) << 20
    }
}

/// Flight-recorder knobs (see [`crate::trace`]).
///
/// The recorder defaults **on** — recording a span is a handful of
/// relaxed atomic stores into a fixed ring, cheap enough for production
/// (the loadtest overhead gate asserts it). `slow_ms` switches on the
/// slow-request stderr log (`--trace-slow-ms`); 0 keeps it off.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceSettings {
    /// Record spans into the flight recorder.
    pub enabled: bool,
    /// Spans the flight recorder retains (rounded up to a power of two;
    /// ~48 bytes each).
    pub ring_capacity: usize,
    /// Emit a single-line JSON report to stderr for requests slower than
    /// this many milliseconds (0 = disabled).
    pub slow_ms: u64,
}

impl Default for TraceSettings {
    fn default() -> Self {
        Self { enabled: true, ring_capacity: crate::trace::DEFAULT_RING_CAPACITY, slow_ms: 0 }
    }
}

/// Runtime kernel-autotuner knobs (see [`crate::linalg::autotune`]).
///
/// Off by default: probing costs a few multiplies per configured size at
/// startup. When enabled (`--autotune`), worker engines race the CPU
/// matmul variants at each size in `sizes`, record the winners in the
/// process-global tuning table, and `CpuAlgo::Auto` / the pool cost
/// model dispatch through it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AutotuneConfig {
    /// Probe kernel variants at worker startup and dispatch through the
    /// recorded winners.
    pub enabled: bool,
    /// Timed probes per `(size, variant)` pair — best-of-k absorbs
    /// scheduling noise (`--autotune-probes`).
    pub probes: usize,
    /// Matrix sizes the tuner races at startup.
    pub sizes: Vec<usize>,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        Self { enabled: false, probes: 3, sizes: vec![64, 128, 256] }
    }
}

/// Cluster-router knobs (see [`crate::cluster`]; driven by
/// `matexp route`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterSettings {
    /// Member addresses (`host:port`) the router fans out to. Empty means
    /// "no cluster": `matexp route` refuses to start.
    pub members: Vec<String>,
    /// Outstanding requests per member at which the router stops routing
    /// to it; when every live member is at the threshold, new work is
    /// shed with a typed [`MatexpError::Admission`].
    pub shed_at: usize,
    /// Milliseconds between health probes of each member.
    pub health_ms: u64,
    /// Egress reconnect attempts per broken member connection before the
    /// router marks the member down.
    pub reconnect_attempts: u32,
    /// First egress reconnect delay, milliseconds (doubles per attempt,
    /// capped internally).
    pub reconnect_base_ms: u64,
}

impl Default for ClusterSettings {
    fn default() -> Self {
        Self {
            members: Vec::new(),
            shed_at: 64,
            health_ms: 500,
            reconnect_attempts: 5,
            reconnect_base_ms: 50,
        }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct MatexpConfig {
    /// Which execution backend engines run on (`cpu` is the default and
    /// needs nothing beyond this crate; `pjrt` needs the `xla` feature +
    /// artifacts; `sim` is the calibrated C2050 timing model).
    pub backend: BackendKind,
    /// CPU matmul variant the `cpu` backend executes launches with.
    pub cpu_algo: CpuAlgo,
    /// Directory holding `manifest.json` + `*.hlo.txt` (from `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Which kernel variant the PJRT backend executes.
    pub variant: Variant,
    /// Worker threads in the serving coordinator.
    pub workers: usize,
    /// Largest matrix size admission control accepts (per side); requests
    /// above it are rejected with a typed [`MatexpError::Admission`].
    pub max_n: usize,
    /// TCP bind address for `matexp serve`.
    pub server_addr: String,
    /// Dynamic-batcher knobs (coalescing size/deadline, queue bound).
    pub batcher: BatcherConfig,
    /// Multi-device pool layout (used when `backend` is `pool`).
    pub pool: PoolConfig,
    /// Multi-tier cache policy (plan memoization, result serving).
    pub cache: CacheSettings,
    /// Persistent artifact-store policy (spill-to-disk, warm restarts).
    pub store: StoreSettings,
    /// Flight-recorder tracing policy (span ring, slow-request log).
    pub trace: TraceSettings,
    /// Cluster-router policy (members, shedding, health cadence) for
    /// `matexp route`.
    pub cluster: ClusterSettings,
    /// Runtime kernel-autotuner policy (startup probing, probe budget).
    pub autotune: AutotuneConfig,
    /// Use the fused `sqmul` executable in binary plans.
    pub fused_sqmul: bool,
    /// Fold squaring runs into `square2`/`square4` launches.
    pub use_square_chains: bool,
    /// Matrix sizes every worker pre-compiles AND pre-executes at startup
    /// (XLA CPU pays ~4 ms thunk-init on an executable's first run; warm
    /// workers serve their first real request at steady-state latency).
    pub warmup_sizes: Vec<usize>,
    /// Workload seed for experiments.
    pub seed: u64,
    /// For the sequential-CPU experiment arm: measure at most this many
    /// multiplies and extrapolate linearly (naive CPU at n=512, N=512
    /// would run for minutes; per-multiply cost is constant in N).
    pub cpu_measure_cap: usize,
}

impl Default for MatexpConfig {
    fn default() -> Self {
        Self {
            backend: BackendKind::Cpu,
            cpu_algo: CpuAlgo::Blocked,
            artifacts_dir: default_artifacts_dir(),
            variant: Variant::Xla,
            workers: 4,
            max_n: 4096,
            server_addr: "127.0.0.1:7070".into(),
            batcher: BatcherConfig::default(),
            pool: PoolConfig::default(),
            cache: CacheSettings::default(),
            store: StoreSettings::default(),
            trace: TraceSettings::default(),
            cluster: ClusterSettings::default(),
            autotune: AutotuneConfig::default(),
            fused_sqmul: true,
            use_square_chains: true,
            warmup_sizes: Vec::new(),
            seed: 42,
            cpu_measure_cap: 8,
        }
    }
}

/// `$MATEXP_ARTIFACTS`, else `./artifacts` relative to the current dir,
/// else the repo-root artifacts dir next to the executable's manifest.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MATEXP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    // fall back to the crate root (useful under `cargo test` / `cargo bench`)
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn bad(field: &str) -> MatexpError {
    MatexpError::Config(format!("config field {field:?} has the wrong type"))
}

impl MatexpConfig {
    /// Build from parsed JSON; missing fields take their defaults,
    /// mistyped fields error.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = MatexpConfig::default();
        let obj = v.as_obj().ok_or_else(|| bad("<root>"))?;
        for (key, val) in obj {
            match key.as_str() {
                "backend" => {
                    cfg.backend =
                        BackendKind::from_str(val.as_str().ok_or_else(|| bad("backend"))?)?;
                }
                "cpu_algo" => {
                    cfg.cpu_algo =
                        CpuAlgo::from_str(val.as_str().ok_or_else(|| bad("cpu_algo"))?)?;
                }
                "artifacts_dir" => {
                    cfg.artifacts_dir =
                        PathBuf::from(val.as_str().ok_or_else(|| bad("artifacts_dir"))?);
                }
                "variant" => {
                    cfg.variant =
                        Variant::from_str(val.as_str().ok_or_else(|| bad("variant"))?)?;
                }
                "workers" => cfg.workers = val.as_usize().ok_or_else(|| bad("workers"))?,
                "max_n" => cfg.max_n = val.as_usize().ok_or_else(|| bad("max_n"))?,
                "pool" => {
                    let p = val.as_obj().ok_or_else(|| bad("pool"))?;
                    for (pk, pv) in p {
                        match pk.as_str() {
                            "devices" => {
                                let arr =
                                    pv.as_arr().ok_or_else(|| bad("pool.devices"))?;
                                let mut devices = Vec::with_capacity(arr.len());
                                for d in arr {
                                    let s = d
                                        .as_str()
                                        .ok_or_else(|| bad("pool.devices"))?;
                                    devices.push(PoolDeviceKind::from_str(s)?);
                                }
                                cfg.pool.devices = devices;
                            }
                            "shard_min_n" => {
                                cfg.pool.shard_min_n =
                                    pv.as_usize().ok_or_else(|| bad("pool.shard_min_n"))?
                            }
                            "grid" => {
                                cfg.pool.grid = if pv.is_null() {
                                    None
                                } else {
                                    Some(pv.as_usize().ok_or_else(|| bad("pool.grid"))?)
                                };
                            }
                            "max_grid" => {
                                cfg.pool.max_grid =
                                    pv.as_usize().ok_or_else(|| bad("pool.max_grid"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field pool.{other}"
                                )))
                            }
                        }
                    }
                }
                "server_addr" => {
                    cfg.server_addr =
                        val.as_str().ok_or_else(|| bad("server_addr"))?.to_string();
                }
                "batcher" => {
                    let b = val.as_obj().ok_or_else(|| bad("batcher"))?;
                    for (bk, bv) in b {
                        match bk.as_str() {
                            "max_batch" => {
                                cfg.batcher.max_batch =
                                    bv.as_usize().ok_or_else(|| bad("batcher.max_batch"))?
                            }
                            "max_wait_ms" => {
                                cfg.batcher.max_wait_ms =
                                    bv.as_u64().ok_or_else(|| bad("batcher.max_wait_ms"))?
                            }
                            "max_queue" => {
                                cfg.batcher.max_queue =
                                    bv.as_usize().ok_or_else(|| bad("batcher.max_queue"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field batcher.{other}"
                                )))
                            }
                        }
                    }
                }
                "cache" => {
                    let c = val.as_obj().ok_or_else(|| bad("cache"))?;
                    for (ck, cv) in c {
                        match ck.as_str() {
                            "plans" => {
                                cfg.cache.plans =
                                    cv.as_bool().ok_or_else(|| bad("cache.plans"))?
                            }
                            "results" => {
                                cfg.cache.results =
                                    cv.as_bool().ok_or_else(|| bad("cache.results"))?
                            }
                            "budget_mb" => {
                                cfg.cache.budget_mb =
                                    cv.as_usize().ok_or_else(|| bad("cache.budget_mb"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field cache.{other}"
                                )))
                            }
                        }
                    }
                }
                "store" => {
                    let s = val.as_obj().ok_or_else(|| bad("store"))?;
                    for (sk, sv) in s {
                        match sk.as_str() {
                            "dir" => {
                                cfg.store.dir = if sv.is_null() {
                                    None
                                } else {
                                    Some(PathBuf::from(
                                        sv.as_str().ok_or_else(|| bad("store.dir"))?,
                                    ))
                                };
                            }
                            "budget_mb" => {
                                cfg.store.budget_mb =
                                    sv.as_usize().ok_or_else(|| bad("store.budget_mb"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field store.{other}"
                                )))
                            }
                        }
                    }
                }
                "trace" => {
                    let t = val.as_obj().ok_or_else(|| bad("trace"))?;
                    for (tk, tv) in t {
                        match tk.as_str() {
                            "enabled" => {
                                cfg.trace.enabled =
                                    tv.as_bool().ok_or_else(|| bad("trace.enabled"))?
                            }
                            "ring_capacity" => {
                                cfg.trace.ring_capacity =
                                    tv.as_usize().ok_or_else(|| bad("trace.ring_capacity"))?
                            }
                            "slow_ms" => {
                                cfg.trace.slow_ms =
                                    tv.as_u64().ok_or_else(|| bad("trace.slow_ms"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field trace.{other}"
                                )))
                            }
                        }
                    }
                }
                "cluster" => {
                    let c = val.as_obj().ok_or_else(|| bad("cluster"))?;
                    for (ck, cv) in c {
                        match ck.as_str() {
                            "members" => {
                                let arr = cv.as_arr().ok_or_else(|| bad("cluster.members"))?;
                                let mut members = Vec::with_capacity(arr.len());
                                for m in arr {
                                    members.push(
                                        m.as_str()
                                            .ok_or_else(|| bad("cluster.members"))?
                                            .to_string(),
                                    );
                                }
                                cfg.cluster.members = members;
                            }
                            "shed_at" => {
                                cfg.cluster.shed_at =
                                    cv.as_usize().ok_or_else(|| bad("cluster.shed_at"))?
                            }
                            "health_ms" => {
                                cfg.cluster.health_ms =
                                    cv.as_u64().ok_or_else(|| bad("cluster.health_ms"))?
                            }
                            "reconnect_attempts" => {
                                cfg.cluster.reconnect_attempts = cv
                                    .as_u64()
                                    .ok_or_else(|| bad("cluster.reconnect_attempts"))?
                                    as u32
                            }
                            "reconnect_base_ms" => {
                                cfg.cluster.reconnect_base_ms = cv
                                    .as_u64()
                                    .ok_or_else(|| bad("cluster.reconnect_base_ms"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field cluster.{other}"
                                )))
                            }
                        }
                    }
                }
                "autotune" => {
                    let a = val.as_obj().ok_or_else(|| bad("autotune"))?;
                    for (ak, av) in a {
                        match ak.as_str() {
                            "enabled" => {
                                cfg.autotune.enabled =
                                    av.as_bool().ok_or_else(|| bad("autotune.enabled"))?
                            }
                            "probes" => {
                                cfg.autotune.probes =
                                    av.as_usize().ok_or_else(|| bad("autotune.probes"))?
                            }
                            "sizes" => {
                                cfg.autotune.sizes =
                                    av.as_usize_vec().ok_or_else(|| bad("autotune.sizes"))?
                            }
                            other => {
                                return Err(MatexpError::Config(format!(
                                    "unknown config field autotune.{other}"
                                )))
                            }
                        }
                    }
                }
                "fused_sqmul" => {
                    cfg.fused_sqmul = val.as_bool().ok_or_else(|| bad("fused_sqmul"))?
                }
                "use_square_chains" => {
                    cfg.use_square_chains =
                        val.as_bool().ok_or_else(|| bad("use_square_chains"))?
                }
                "warmup_sizes" => {
                    cfg.warmup_sizes =
                        val.as_usize_vec().ok_or_else(|| bad("warmup_sizes"))?;
                }
                "seed" => cfg.seed = val.as_u64().ok_or_else(|| bad("seed"))?,
                "cpu_measure_cap" => {
                    cfg.cpu_measure_cap =
                        val.as_usize().ok_or_else(|| bad("cpu_measure_cap"))?
                }
                other => {
                    return Err(MatexpError::Config(format!("unknown config field {other:?}")))
                }
            }
        }
        Ok(cfg)
    }

    /// Serialize (for `matexp info --config` and config-file scaffolding).
    pub fn to_json(&self) -> Json {
        json_obj![
            ("backend", self.backend.as_str()),
            ("cpu_algo", self.cpu_algo.name()),
            ("artifacts_dir", self.artifacts_dir.display().to_string()),
            ("variant", self.variant.as_str()),
            ("workers", self.workers),
            ("max_n", self.max_n),
            ("server_addr", self.server_addr.as_str()),
            (
                "batcher",
                json_obj![
                    ("max_batch", self.batcher.max_batch),
                    ("max_wait_ms", self.batcher.max_wait_ms),
                    ("max_queue", self.batcher.max_queue),
                ]
            ),
            (
                "pool",
                json_obj![
                    (
                        "devices",
                        Json::Arr(
                            self.pool
                                .devices
                                .iter()
                                .map(|d| Json::Str(d.as_str().to_string()))
                                .collect()
                        )
                    ),
                    ("shard_min_n", self.pool.shard_min_n),
                    (
                        "grid",
                        match self.pool.grid {
                            Some(g) => Json::from(g),
                            None => Json::Null,
                        }
                    ),
                    ("max_grid", self.pool.max_grid),
                ]
            ),
            (
                "cache",
                json_obj![
                    ("plans", self.cache.plans),
                    ("results", self.cache.results),
                    ("budget_mb", self.cache.budget_mb),
                ]
            ),
            (
                "store",
                json_obj![
                    (
                        "dir",
                        match &self.store.dir {
                            Some(d) => Json::Str(d.display().to_string()),
                            None => Json::Null,
                        }
                    ),
                    ("budget_mb", self.store.budget_mb),
                ]
            ),
            (
                "trace",
                json_obj![
                    ("enabled", self.trace.enabled),
                    ("ring_capacity", self.trace.ring_capacity),
                    ("slow_ms", self.trace.slow_ms),
                ]
            ),
            (
                "cluster",
                json_obj![
                    (
                        "members",
                        Json::Arr(
                            self.cluster
                                .members
                                .iter()
                                .map(|m| Json::Str(m.clone()))
                                .collect()
                        )
                    ),
                    ("shed_at", self.cluster.shed_at),
                    ("health_ms", self.cluster.health_ms),
                    ("reconnect_attempts", u64::from(self.cluster.reconnect_attempts)),
                    ("reconnect_base_ms", self.cluster.reconnect_base_ms),
                ]
            ),
            (
                "autotune",
                json_obj![
                    ("enabled", self.autotune.enabled),
                    ("probes", self.autotune.probes),
                    (
                        "sizes",
                        Json::Arr(
                            self.autotune.sizes.iter().map(|&n| Json::from(n)).collect()
                        )
                    ),
                ]
            ),
            (
                "warmup_sizes",
                Json::Arr(self.warmup_sizes.iter().map(|&n| Json::from(n)).collect())
            ),
            ("fused_sqmul", self.fused_sqmul),
            ("use_square_chains", self.use_square_chains),
            ("seed", self.seed),
            ("cpu_measure_cap", self.cpu_measure_cap),
        ]
    }

    /// Load from a JSON file; missing fields take their defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| MatexpError::Config(format!("{}: {e}", path.display())))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Validate invariants; call after all overrides are applied.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(MatexpError::Config("workers must be >= 1".into()));
        }
        if self.batcher.max_batch == 0 {
            return Err(MatexpError::Config("batcher.max_batch must be >= 1".into()));
        }
        if self.cpu_measure_cap == 0 {
            return Err(MatexpError::Config("cpu_measure_cap must be >= 1".into()));
        }
        if self.max_n == 0 {
            return Err(MatexpError::Config("max_n must be >= 1".into()));
        }
        if self.cache.budget_mb == 0 {
            return Err(MatexpError::Config("cache.budget_mb must be >= 1".into()));
        }
        if self.store.budget_mb == 0 {
            return Err(MatexpError::Config("store.budget_mb must be >= 1".into()));
        }
        if self.trace.ring_capacity == 0 {
            return Err(MatexpError::Config("trace.ring_capacity must be >= 1".into()));
        }
        if self.pool.max_grid == 0 {
            return Err(MatexpError::Config("pool.max_grid must be >= 1".into()));
        }
        if self.pool.grid == Some(0) {
            return Err(MatexpError::Config("pool.grid must be >= 1".into()));
        }
        if self.autotune.probes == 0 {
            return Err(MatexpError::Config("autotune.probes must be >= 1".into()));
        }
        if self.autotune.enabled && self.autotune.sizes.is_empty() {
            return Err(MatexpError::Config(
                "autotune.sizes must list at least one size when autotune is enabled".into(),
            ));
        }
        if self.autotune.sizes.contains(&0) {
            return Err(MatexpError::Config("autotune.sizes entries must be >= 1".into()));
        }
        if self.backend == BackendKind::Pool && self.pool.devices.is_empty() {
            return Err(MatexpError::Config(
                "backend \"pool\" needs at least one device in pool.devices".into(),
            ));
        }
        if self.cluster.shed_at == 0 {
            return Err(MatexpError::Config("cluster.shed_at must be >= 1".into()));
        }
        if self.cluster.health_ms == 0 {
            return Err(MatexpError::Config("cluster.health_ms must be >= 1".into()));
        }
        if self.cluster.reconnect_attempts == 0 {
            return Err(MatexpError::Config("cluster.reconnect_attempts must be >= 1".into()));
        }
        for m in &self.cluster.members {
            if !m.contains(':') {
                return Err(MatexpError::Config(format!(
                    "cluster.members entry {m:?} is not a host:port address"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        MatexpConfig::default().validate().unwrap();
    }

    #[test]
    fn partial_json_fills_defaults() {
        let cfg =
            MatexpConfig::from_json(&Json::parse(r#"{"workers": 8}"#).unwrap()).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.batcher.max_batch, BatcherConfig::default().max_batch);
        assert_eq!(cfg.variant, Variant::Xla);
        assert_eq!(cfg.backend, BackendKind::Cpu);
        assert_eq!(cfg.cpu_algo, CpuAlgo::Blocked);
    }

    #[test]
    fn backend_and_cpu_algo_parse() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(r#"{"backend": "sim", "cpu_algo": "threaded"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.backend, BackendKind::Sim);
        assert_eq!(cfg.cpu_algo, CpuAlgo::Threaded);
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"backend": "tpu"}"#).unwrap()
        )
        .is_err());
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"cpu_algo": "gpu"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn nested_batcher_overrides() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(r#"{"batcher": {"max_wait_ms": 9}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.batcher.max_wait_ms, 9);
        assert_eq!(cfg.batcher.max_batch, BatcherConfig::default().max_batch);
    }

    #[test]
    fn unknown_and_mistyped_fields_rejected() {
        assert!(MatexpConfig::from_json(&Json::parse(r#"{"wrkers": 8}"#).unwrap()).is_err());
        assert!(
            MatexpConfig::from_json(&Json::parse(r#"{"workers": "8"}"#).unwrap()).is_err()
        );
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"variant": "cuda"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn bad_values_rejected() {
        let mut cfg = MatexpConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.batcher.max_batch = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn pool_config_parses() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(
                r#"{"backend":"pool","pool":{"devices":["cpu","sim"],"shard_min_n":128,"grid":2}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.backend, BackendKind::Pool);
        assert_eq!(cfg.pool.devices, vec![PoolDeviceKind::Cpu, PoolDeviceKind::Sim]);
        assert_eq!(cfg.pool.shard_min_n, 128);
        assert_eq!(cfg.pool.grid, Some(2));
        cfg.validate().unwrap();
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"pool":{"devices":["tpu"]}}"#).unwrap()
        )
        .is_err());
        assert!(MatexpConfig::from_json(&Json::parse(r#"{"pool":{"wat":1}}"#).unwrap()).is_err());
    }

    #[test]
    fn max_n_and_pool_validate() {
        let mut cfg = MatexpConfig::default();
        cfg.max_n = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Pool;
        cfg.pool.devices.clear();
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.pool.grid = Some(0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cache_settings_parse_and_validate() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(r#"{"cache":{"results":true,"budget_mb":32,"plans":false}}"#).unwrap(),
        )
        .unwrap();
        assert!(cfg.cache.results && !cfg.cache.plans);
        assert_eq!(cfg.cache.budget_mb, 32);
        assert_eq!(cfg.cache.budget_bytes(), 32 << 20);
        cfg.validate().unwrap();
        // unknown nested fields and bad types rejected
        assert!(MatexpConfig::from_json(&Json::parse(r#"{"cache":{"wat":1}}"#).unwrap()).is_err());
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"cache":{"results":"yes"}}"#).unwrap()
        )
        .is_err());
        // a zero budget is a config error
        let mut cfg = MatexpConfig::default();
        cfg.cache.budget_mb = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn store_settings_parse_and_validate() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(r#"{"store":{"dir":"/tmp/s","budget_mb":64}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.store.dir, Some(PathBuf::from("/tmp/s")));
        assert_eq!(cfg.store.budget_mb, 64);
        assert_eq!(cfg.store.budget_bytes(), 64 << 20);
        cfg.validate().unwrap();
        // a null dir is the explicit "persistence off"
        let cfg =
            MatexpConfig::from_json(&Json::parse(r#"{"store":{"dir":null}}"#).unwrap()).unwrap();
        assert_eq!(cfg.store.dir, None);
        // defaults: off, 1 GiB budget
        let d = StoreSettings::default();
        assert!(d.dir.is_none());
        assert_eq!(d.budget_mb, 1024);
        // unknown nested fields and bad types rejected
        assert!(MatexpConfig::from_json(&Json::parse(r#"{"store":{"wat":1}}"#).unwrap()).is_err());
        assert!(
            MatexpConfig::from_json(&Json::parse(r#"{"store":{"dir":7}}"#).unwrap()).is_err()
        );
        // a zero budget is a config error
        let mut cfg = MatexpConfig::default();
        cfg.store.budget_mb = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn trace_settings_parse_and_validate() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(r#"{"trace":{"enabled":false,"ring_capacity":512,"slow_ms":25}}"#)
                .unwrap(),
        )
        .unwrap();
        assert!(!cfg.trace.enabled);
        assert_eq!(cfg.trace.ring_capacity, 512);
        assert_eq!(cfg.trace.slow_ms, 25);
        cfg.validate().unwrap();
        // defaults: recorder on, slow log off
        let d = TraceSettings::default();
        assert!(d.enabled);
        assert_eq!(d.slow_ms, 0);
        assert!(MatexpConfig::from_json(&Json::parse(r#"{"trace":{"wat":1}}"#).unwrap()).is_err());
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"trace":{"enabled":"on"}}"#).unwrap()
        )
        .is_err());
        let mut cfg = MatexpConfig::default();
        cfg.trace.ring_capacity = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn autotune_settings_parse_and_validate() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(r#"{"autotune":{"enabled":true,"probes":5,"sizes":[32,64]}}"#)
                .unwrap(),
        )
        .unwrap();
        assert!(cfg.autotune.enabled);
        assert_eq!(cfg.autotune.probes, 5);
        assert_eq!(cfg.autotune.sizes, vec![32, 64]);
        cfg.validate().unwrap();
        // defaults: tuner off, sane probe budget
        let d = AutotuneConfig::default();
        assert!(!d.enabled && d.probes >= 1 && !d.sizes.is_empty());
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"autotune":{"wat":1}}"#).unwrap()
        )
        .is_err());
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"autotune":{"enabled":"on"}}"#).unwrap()
        )
        .is_err());
        let mut cfg = MatexpConfig::default();
        cfg.autotune.probes = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.autotune.enabled = true;
        cfg.autotune.sizes.clear();
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.autotune.sizes.push(0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cluster_settings_parse_and_validate() {
        let cfg = MatexpConfig::from_json(
            &Json::parse(
                r#"{"cluster":{"members":["a:1","b:2"],"shed_at":8,"health_ms":100,
                    "reconnect_attempts":3,"reconnect_base_ms":10}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.cluster.members, vec!["a:1".to_string(), "b:2".to_string()]);
        assert_eq!(cfg.cluster.shed_at, 8);
        assert_eq!(cfg.cluster.health_ms, 100);
        assert_eq!(cfg.cluster.reconnect_attempts, 3);
        assert_eq!(cfg.cluster.reconnect_base_ms, 10);
        cfg.validate().unwrap();
        // defaults: no members (route refuses), sane thresholds
        let d = ClusterSettings::default();
        assert!(d.members.is_empty() && d.shed_at >= 1 && d.health_ms >= 1);
        assert!(
            MatexpConfig::from_json(&Json::parse(r#"{"cluster":{"wat":1}}"#).unwrap()).is_err()
        );
        assert!(MatexpConfig::from_json(
            &Json::parse(r#"{"cluster":{"members":"a:1"}}"#).unwrap()
        )
        .is_err());
        let mut cfg = MatexpConfig::default();
        cfg.cluster.shed_at = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.cluster.health_ms = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.cluster.reconnect_attempts = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = MatexpConfig::default();
        cfg.cluster.members.push("noport".into());
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn roundtrips_through_json() {
        let cfg = MatexpConfig::default();
        let s = cfg.to_json().to_string_pretty();
        assert_eq!(MatexpConfig::from_json(&Json::parse(&s).unwrap()).unwrap(), cfg);
    }

    #[test]
    fn from_file_missing_is_error() {
        assert!(MatexpConfig::from_file(Path::new("/nonexistent/cfg.json")).is_err());
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let path = dir.file("cfg.json");
        let mut cfg = MatexpConfig::default();
        cfg.workers = 2;
        cfg.variant = Variant::Pallas;
        std::fs::write(&path, cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(MatexpConfig::from_file(&path).unwrap(), cfg);
    }
}
