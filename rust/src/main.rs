//! `matexp` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//! * `info`       — platform, artifact inventory, device table (paper Table 1)
//! * `plan`       — show the launch schedule for a power (all planners)
//! * `expm`       — compute `A^N` once, printing stats (any method)
//! * `experiment` — regenerate a paper table+figures or an ablation
//! * `serve`      — run the TCP serving front-end
//! * `route`      — run the cluster router in front of N `serve` members
//! * `loadtest`   — drive a server with concurrent wire clients, write a
//!   `BENCH_*.json` latency/throughput snapshot
//! * `trace`      — dump a running server's flight recorder as Chrome
//!   trace-event JSON (Perfetto / `chrome://tracing` loadable)
//! * `metrics`    — fetch a running server's metrics (JSON or Prometheus)
//! * `bench-report` — run every table in simulation and print the summary

use std::str::FromStr;
use std::sync::Arc;

use matexp::bench::loadtest::{self, LoadtestConfig, WireMode};
use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::error::{MatexpError, Result};
use matexp::exec::{Executor, Priority, Submission};
use matexp::experiments::{self, ablations, report};
use matexp::linalg::matrix::Matrix;
use matexp::linalg::CpuAlgo;
use matexp::plan::{Plan, PlanCost};
use matexp::runtime::artifacts::ArtifactRegistry;
use matexp::runtime::engine::AnyEngine;
use matexp::runtime::{BackendKind, Variant};
use matexp::server::client::MatexpClient;
use matexp::simulator::device::DeviceSpec;
use matexp::util::cli::Args;

const USAGE: &str = "\
matexp — heterogeneous highly parallel matrix exponentiation (IJDPS 2012 repro)

USAGE: matexp <command> [flags]

COMMANDS:
  info         platform + artifact inventory [--device c2050|xeon]
  plan         show launch schedules   --power N [--all]
  expm         compute A^N             --n SIZE --power N [--method M] [--seed S]
                                       [--deadline-ms MS] [--tolerance T]
                                       [--priority low|normal|high] [--explain]
                                       (--explain: per-stage latency breakdown
                                        + cache-tier outcomes)
  experiment   regenerate paper results --table 2..5 [--measure] [--figures]
               or an ablation          --ablation tiles|transfers|fusion|cpu
                                       [--n SIZE] [--power N]
               or the pool scaling run --pool-scaling [--n SIZE] [--measure]
                                       [--max-devices K]
               or the residency ablation --ablate-residency [--n SIZE]
                                       [--steps K] [--power N] [--measure]
                                       (clone-per-launch vs resident buffers
                                        at n in {256,512,1024} by default)
               or the cache ablation   --ablate-cache [--n SIZE] [--power N]
                                       [--iters K] [--measure]
                                       (A6: cold vs plan-warm vs result-warm
                                        at n in {256,512,1024} by default)
               or the kernel ablation  --ablate-kernels [--n SIZE]
                                       (A7: every CpuAlgo single-multiply,
                                        GFLOP/s + speedup vs blocked,
                                        at n in {256,512,1024} by default)
  serve        TCP front-end           [--addr HOST:PORT] [--workers W]
  route        cluster router          --members A:1,B:2,… [--addr HOST:PORT]
                                       [--shed-at K] [--health-ms MS]
                                       (content-affinity fan-out over running
                                        `matexp serve` members; same wire
                                        protocol in as a single server)
  trace        dump a server's flight recorder as Chrome trace JSON
                                       [--addr HOST:PORT] [--out FILE]
                                       [--check]  (validate, print span count)
  metrics      fetch server metrics    [--addr HOST:PORT]
                                       [--format json|prometheus]
  loadtest     wire load harness       [--addr HOST:PORT] [--clients K]
                                       [--requests R] [--warmup W] [--n SIZE]
                                       [--power N] [--method M] [--rate RPS]
                                       [--wire json|base64|binary|all]
                                       [--codec-n SIZE] [--bench-id ID]
                                       [--out FILE]
                                       (no --addr: serves itself in-process;
                                        --rate: open loop at RPS per client;
                                        --check FILE: validate a snapshot
                                        and exit)
  bench-report all tables, simulation-only summary

GLOBAL FLAGS:
  --backend cpu|sim|pjrt|pool   execution backend (default cpu; pjrt needs
                           the `xla` cargo feature + `make artifacts`;
                           pool = heterogeneous multi-device)
  --cpu-algo naive|transposed|ikj|blocked|threaded|packed|simd|strassen|auto
  --autotune        probe CPU kernel variants at startup; winners steer
                    cpu-algo auto dispatch + the Strassen plan threshold
  --autotune-probes K   best-of-K timing per autotuner probe (default 3)
  --pool-devices LIST   pool members, e.g. cpu,sim,sim (backend pool)
  --pool-grid G     force the pool tile grid to GxG (default: cost model)
  --shard-min-n N   smallest matrix the pool tile-shards (default 512)
  --max-n N         admission limit on matrix size (default 4096)
  --cache-results   serve repeated identical requests from the result cache
  --cache-budget-mb M   result-cache byte budget, MiB (default 256, LRU)
  --store-dir DIR   persistent artifact store: results spill to disk
                    instead of evicting, autotune table and plans
                    survive restarts (default off)
  --store-budget-mb M   on-disk store byte budget, MiB (default 1024)
  --trace / --no-trace  flight-recorder span capture (default on)
  --trace-ring N    spans the flight recorder retains (default 4096)
  --trace-slow-ms MS    stderr JSON line for requests slower than MS (0 = off)
  --artifacts DIR   artifact directory (default ./artifacts or $MATEXP_ARTIFACTS)
  --variant xla|pallas
  --config FILE     JSON config file
  --help

METHODS: ours | ours-packed | ours-chained | addition-chain | fused-artifact
         | naive-gpu | plan-roundtrip | cpu-seq
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.has("help") || args.command.is_none() {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Build the config from defaults → --config file → flags.
fn load_config(args: &Args) -> Result<MatexpConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => MatexpConfig::from_file(std::path::Path::new(path))?,
        None => MatexpConfig::default(),
    };
    if let Some(b) = args.get("backend") {
        cfg.backend = BackendKind::from_str(b)?;
    }
    if let Some(a) = args.get("cpu-algo") {
        cfg.cpu_algo = CpuAlgo::from_str(a)?;
    }
    if args.has("autotune") {
        cfg.autotune.enabled = true;
        // autotuning exists to steer dispatch: unless the user pinned a
        // specific kernel, route CPU multiplies through the winner table
        if args.get("cpu-algo").is_none() {
            cfg.cpu_algo = CpuAlgo::Auto;
        }
    }
    if let Some(p) = args.get_parsed::<usize>("autotune-probes")? {
        cfg.autotune.probes = p;
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.into();
    }
    if let Some(v) = args.get("variant") {
        cfg.variant = Variant::from_str(v)?;
    }
    if let Some(w) = args.get_parsed::<usize>("workers")? {
        cfg.workers = w;
    }
    if let Some(addr) = args.get("addr") {
        cfg.server_addr = addr.to_string();
    }
    if let Some(seed) = args.get_parsed::<u64>("seed")? {
        cfg.seed = seed;
    }
    if let Some(list) = args.get("pool-devices") {
        cfg.pool.devices = matexp::pool::parse_device_list(list)?;
    }
    if let Some(g) = args.get_parsed::<usize>("pool-grid")? {
        cfg.pool.grid = Some(g);
    }
    if let Some(n) = args.get_parsed::<usize>("shard-min-n")? {
        cfg.pool.shard_min_n = n;
    }
    if let Some(n) = args.get_parsed::<usize>("max-n")? {
        cfg.max_n = n;
    }
    if args.has("cache-results") {
        cfg.cache.results = true;
    }
    if let Some(mb) = args.get_parsed::<usize>("cache-budget-mb")? {
        cfg.cache.budget_mb = mb;
    }
    if let Some(dir) = args.get("store-dir") {
        cfg.store.dir = Some(dir.into());
    }
    if let Some(mb) = args.get_parsed::<usize>("store-budget-mb")? {
        cfg.store.budget_mb = mb;
    }
    if args.has("trace") {
        cfg.trace.enabled = true;
    }
    if args.has("no-trace") {
        cfg.trace.enabled = false;
    }
    if let Some(cap) = args.get_parsed::<usize>("trace-ring")? {
        cfg.trace.ring_capacity = cap;
    }
    if let Some(ms) = args.get_parsed::<u64>("trace-slow-ms")? {
        cfg.trace.slow_ms = ms;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // arm the flight recorder for every command (the service configures
    // it again at start, idempotently, from the same settings)
    matexp::trace::configure(&cfg.trace);
    match args.command.as_deref().unwrap_or("") {
        "info" => cmd_info(args, &cfg),
        "plan" => cmd_plan(args),
        "expm" => cmd_expm(args, &cfg),
        "experiment" => cmd_experiment(args, &cfg),
        "serve" => cmd_serve(args, cfg),
        "route" => cmd_route(args, cfg),
        "trace" => cmd_trace(args, &cfg),
        "metrics" => cmd_metrics(args, &cfg),
        "loadtest" => cmd_loadtest(args, cfg),
        "bench-report" => cmd_bench_report(args, &cfg),
        other => Err(MatexpError::Config(format!(
            "unknown command {other:?}; see --help"
        ))),
    }
}

fn cmd_info(args: &Args, cfg: &MatexpConfig) -> Result<()> {
    let device = args.get_or("device", "c2050");
    args.reject_unknown()?;
    let spec = match device.as_str() {
        "c2050" => DeviceSpec::tesla_c2050(),
        "xeon" => DeviceSpec::xeon_2012_single_core(),
        other => return Err(MatexpError::Config(format!("unknown device {other:?}"))),
    };
    println!("== paper Table 1: device specification ==");
    for (k, v) in spec.table1_rows() {
        println!("{k:<34} {v}");
    }
    // `info` is the diagnostic command: report an unbuildable backend,
    // don't die on it
    println!("\nbackend : {}", cfg.backend);
    match matexp::coordinator::worker::build_worker_engine(cfg, None) {
        Ok(engine) => println!("platform: {}", engine.platform()),
        Err(e) => println!("platform: unavailable ({e})"),
    }
    match ArtifactRegistry::discover(&cfg.artifacts_dir) {
        Ok(reg) => {
            println!("\n== artifacts ({}) ==", cfg.artifacts_dir.display());
            println!("entries: {}", reg.entries().len());
            for variant in [Variant::Xla, Variant::Pallas] {
                println!("sizes[{variant}]: {:?}", reg.sizes(variant));
            }
            println!("fused expm powers @64: {:?}", reg.fused_expm_powers(64));
        }
        Err(e) => println!("\nartifacts: unavailable ({e}) — cpu/sim backends need none"),
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let power: u64 = args
        .get_parsed("power")?
        .ok_or_else(|| MatexpError::Config("plan needs --power".into()))?;
    let all = args.has("all");
    let n: usize = args.get_parsed_or("n", 256)?;
    args.reject_unknown()?;
    let plans = if all {
        vec![
            Plan::naive(power),
            Plan::binary(power, false),
            Plan::binary(power, true),
            Plan::chained(power, &[4, 2]),
            Plan::addition_chain(power),
        ]
    } else {
        vec![Plan::binary(power, false)]
    };
    println!(
        "{:<16} {:>9} {:>11} {:>14} {:>16}",
        "plan", "launches", "multiplies", "transfers", "transfer bytes"
    );
    for plan in &plans {
        let cost = if plan.kind == matexp::plan::PlanKind::Naive {
            PlanCost::per_launch_roundtrip(plan, n)
        } else {
            PlanCost::device_resident(plan, n)
        };
        println!(
            "{:<16} {:>9} {:>11} {:>14} {:>16.0}",
            plan.kind.to_string(),
            cost.launches,
            cost.multiplies,
            cost.h2d_transfers + cost.d2h_transfers,
            cost.transfer_bytes,
        );
    }
    if !all {
        println!("\nsteps:");
        for (i, step) in plans[0].steps.iter().enumerate() {
            println!("  {i:>3}: {step:?}");
        }
    }
    Ok(())
}

fn cmd_expm(args: &Args, cfg: &MatexpConfig) -> Result<()> {
    let n: usize = args
        .get_parsed("n")?
        .ok_or_else(|| MatexpError::Config("expm needs --n".into()))?;
    let power: u64 = args
        .get_parsed("power")?
        .ok_or_else(|| MatexpError::Config("expm needs --power".into()))?;
    let method = Method::from_str(&args.get_or("method", "ours"))?;
    let deadline_ms: Option<u64> = args.get_parsed("deadline-ms")?;
    let tolerance: Option<f32> = args.get_parsed("tolerance")?;
    let priority = match args.get("priority") {
        Some(p) => Priority::from_str(p)?,
        None => Priority::Normal,
    };
    let explain = args.has("explain");
    args.reject_unknown()?;

    // the one execution surface: CLI runs the same Submission the
    // service and the examples do
    let mut engine = matexp::coordinator::worker::build_worker_engine(cfg, None)?;
    let a = Matrix::random_spectral(n, 0.999, cfg.seed);
    let mut submission = Submission::expm(a, power).method(method).priority(priority);
    if let Some(ms) = deadline_ms {
        submission = submission.deadline(std::time::Duration::from_millis(ms));
    }
    if let Some(t) = tolerance {
        submission = submission.tolerance(t);
    }
    let trace_id = submission.trace;
    let resp = engine.run(submission)?;
    println!("backend: {} ({})", cfg.backend, engine.platform());
    println!("method: {} (plan: {:?})", resp.method, resp.plan_kind);
    println!(
        "launches: {}  multiplies: {}  transfers: {}h2d/{}d2h  wall: {}",
        resp.stats.launches,
        resp.stats.multiplies,
        resp.stats.h2d_transfers,
        resp.stats.d2h_transfers,
        matexp::bench::format_secs(resp.stats.wall_s),
    );
    println!(
        "residency: {} bytes copied, {} buffers recycled, peak {} resident bytes",
        resp.stats.bytes_copied, resp.stats.buffers_recycled, resp.stats.peak_resident_bytes,
    );
    let cache = matexp::cache::stats::snapshot();
    println!(
        "cache: plan {}h/{}m  prepared {}h/{}m  result {}h/{}m ({} entries, {} bytes, {} evicted)",
        cache.plan_hits,
        cache.plan_misses,
        cache.prepared_hits,
        cache.prepared_misses,
        cache.result_hits,
        cache.result_misses,
        cache.result_entries,
        cache.result_bytes,
        cache.result_evictions,
    );
    for d in &resp.stats.per_device {
        println!(
            "  {:<8} launches: {}  multiplies: {}  transfers: {}h2d/{}d2h  busy: {}",
            d.device,
            d.launches,
            d.multiplies,
            d.h2d_transfers,
            d.d2h_transfers,
            matexp::bench::format_secs(d.wall_s),
        );
    }
    println!("result fro-norm: {:.4e}", resp.result.frobenius());
    if explain {
        print_explain(&resp, trace_id);
    }
    Ok(())
}

/// `expm --explain`: the request's per-stage breakdown and cache-tier
/// outcomes, from the stats stage fields and the flight recorder.
fn print_explain(resp: &matexp::coordinator::request::ExpmResponse, trace_id: matexp::trace::TraceId) {
    use matexp::trace::SpanKind;
    println!("\n== explain (trace {}) ==", trace_id.get());
    println!("{:<10} {:>12}", "stage", "time");
    for (stage, us) in [
        ("queue", resp.stats.queue_us),
        ("plan", resp.stats.plan_us),
        ("prepare", resp.stats.prepare_us),
        ("launch", resp.stats.launch_us),
        ("wire", resp.stats.wire_us),
    ] {
        println!(
            "{stage:<10} {:>12}",
            matexp::bench::format_secs(us as f64 / 1e6)
        );
    }
    // cache-tier outcomes, in the order they happened
    let mut outcomes: Vec<String> = matexp::trace::recent_spans()
        .iter()
        .filter(|s| s.trace_id == trace_id.get())
        .filter(|s| {
            matches!(
                s.kind,
                SpanKind::CacheHit(_) | SpanKind::CacheMiss(_) | SpanKind::CacheStore(_)
            )
        })
        .map(|s| s.kind.as_str().to_string())
        .collect();
    if outcomes.is_empty() {
        outcomes.push("none recorded (recorder off or ring overwritten)".into());
    }
    println!("cache: {}", outcomes.join(" -> "));
    // the autotuner's winner table, when a probe pass has run
    let rows = matexp::linalg::autotune::snapshot();
    if rows.is_empty() {
        println!("autotune: off (enable with --autotune)");
    } else {
        let table: Vec<String> = rows
            .iter()
            .map(|r| format!("n={} -> {} ({:.1} GFLOP/s)", r.n, r.winner.name(), r.gflops))
            .collect();
        println!(
            "autotune: {} ({} probes; strassen plans above n={})",
            table.join(", "),
            matexp::linalg::autotune::probes_total(),
            matexp::linalg::autotune::strassen_threshold()
                .map(|t| t.to_string())
                .unwrap_or_else(|| "off".into()),
        );
    }
}

fn cmd_experiment(args: &Args, cfg: &MatexpConfig) -> Result<()> {
    if args.has("ablate-kernels") {
        let ns: Vec<usize> = match args.get_parsed::<usize>("n")? {
            Some(n) => vec![n],
            None => vec![256, 512, 1024],
        };
        args.reject_unknown()?;
        for &n in &ns {
            let arms = ablations::kernel_tier(n, cfg.seed);
            print!("{}", report::render_ablation(&format!("A7 kernel tier (n={n})"), &arms));
            let blocked = arms.iter().find(|a| a.name == "blocked").expect("blocked always runs");
            let best = arms
                .iter()
                .min_by(|x, y| x.wall_s.total_cmp(&y.wall_s))
                .expect("kernel tier is never empty");
            println!(
                "best kernel at n={n}: {} ({:.2}x vs blocked)\n",
                best.name,
                blocked.wall_s / best.wall_s.max(f64::MIN_POSITIVE)
            );
        }
        return Ok(());
    }
    if args.has("ablate-cache") {
        let power: u64 = args.get_parsed_or("power", 1024)?;
        let iters: usize = args.get_parsed_or("iters", 2000)?;
        let measure = args.has("measure");
        let ns: Vec<usize> = match args.get_parsed::<usize>("n")? {
            Some(n) => vec![n],
            None => vec![256, 512, 1024],
        };
        args.reject_unknown()?;
        for &n in &ns {
            let setup = ablations::cache_setup_arms(n, power, iters);
            let title =
                format!("A6 cache setup path (n={n}, N={power}, {iters} requests, exec elided)");
            print!("{}", report::render_ablation(&title, &setup));
            let speedup = setup[0].wall_s / setup[1].wall_s.max(f64::MIN_POSITIVE);
            println!("plan-warm setup is {speedup:.1}x faster than cold per request\n");

            let tiers = ablations::cache_result_arms(n, power, cfg.seed);
            print!(
                "{}",
                report::render_ablation(&format!("A6 result tier (n={n}, N={power})"), &tiers)
            );
            let speedup = tiers[0].wall_s / tiers[1].wall_s.max(f64::MIN_POSITIVE);
            println!(
                "result-warm serving is {speedup:.0}x faster than the modeled cold execution\n"
            );

            if measure {
                let engine_arms = ablations::cache_engine_arms(cfg, n, power)?;
                print!(
                    "{}",
                    report::render_ablation(
                        &format!("A6 cache, full engine (n={n}, N={power}, measured serves)"),
                        &engine_arms
                    )
                );
                let speedup =
                    engine_arms[0].wall_s / engine_arms[2].wall_s.max(f64::MIN_POSITIVE);
                println!("result-warm serve measured {speedup:.0}x faster than cold\n");
            }
        }
        return Ok(());
    }
    if args.has("ablate-residency") {
        let steps: usize = args.get_parsed_or("steps", 10)?;
        let power: u64 = args.get_parsed_or("power", 1024)?;
        let measure = args.has("measure");
        let ns: Vec<usize> = match args.get_parsed::<usize>("n")? {
            Some(n) => vec![n],
            None => vec![256, 512, 1024],
        };
        args.reject_unknown()?;
        for &n in &ns {
            let arms = ablations::residency_data_path_arms(n, steps, cfg.seed);
            print!(
                "{}",
                report::render_ablation(
                    &format!("A5 residency data path (n={n}, {steps}-step chain)"),
                    &arms
                )
            );
            let speedup = arms[0].wall_s / arms[1].wall_s.max(f64::MIN_POSITIVE);
            println!("resident data path is {speedup:.1}x faster than clone-per-launch\n");
            if measure {
                let mut engine = AnyEngine::from_config(cfg)?;
                let engine_arms =
                    ablations::residency_engine_arms(&mut engine, n, power, cfg.seed)?;
                print!(
                    "{}",
                    report::render_ablation(
                        &format!("A5 residency, full engine (n={n}, N={power})"),
                        &engine_arms
                    )
                );
                println!();
            }
        }
        return Ok(());
    }
    if args.has("pool-scaling") {
        let n: usize = args.get_parsed_or("n", 1024)?;
        let measure = args.has("measure");
        let max_devices: usize = args.get_parsed_or("max-devices", usize::MAX)?;
        args.reject_unknown()?;
        let mut arms = experiments::scaling::default_scaling_arms();
        arms.retain(|a| a.len() <= max_devices);
        let t = experiments::run_pool_scaling(cfg, n, &arms, measure)?;
        print!("{}", experiments::render_scaling(&t));
        return Ok(());
    }
    if let Some(table) = args.get_parsed::<u8>("table")? {
        let measure = args.has("measure");
        let figures = args.has("figures");
        args.reject_unknown()?;
        let mut engine: Option<AnyEngine> = if measure {
            Some(AnyEngine::from_config(cfg)?)
        } else {
            None
        };
        let t = experiments::run_table(table, cfg, engine.as_mut())?;
        print!("{}", report::render_table(&t));
        if figures {
            print!("{}", report::render_figures(&t));
        }
        return Ok(());
    }
    if let Some(which) = args.get("ablation") {
        let which = which.to_string();
        let n: usize = args.get_parsed_or("n", 128)?;
        let power: u64 = args.get_parsed_or("power", 256)?;
        args.reject_unknown()?;
        if which == "cpu" {
            let arms = ablations::cpu_variants(n, cfg.seed);
            print!("{}", report::render_ablation(&format!("CPU matmul variants (n={n})"), &arms));
            return Ok(());
        }
        if which == "tiles" {
            return cmd_ablation_tiles(cfg, n);
        }
        let mut engine = AnyEngine::from_config(cfg)?;
        let arms = match which.as_str() {
            "transfers" => ablations::transfer_ablation(&mut engine, n, power, cfg.seed)?,
            "fusion" => ablations::fusion_ablation(&mut engine, n, power, cfg.seed)?,
            other => {
                return Err(MatexpError::Config(format!(
                    "unknown ablation {other:?} (tiles|transfers|fusion|cpu)"
                )))
            }
        };
        print!(
            "{}",
            report::render_ablation(&format!("{which} (n={n}, N={power})"), &arms)
        );
        return Ok(());
    }
    Err(MatexpError::Config(
        "experiment needs --table 2..5, --ablation NAME, or --pool-scaling".into(),
    ))
}

#[cfg(feature = "xla")]
fn cmd_ablation_tiles(cfg: &MatexpConfig, n: usize) -> Result<()> {
    let registry = ArtifactRegistry::discover(&cfg.artifacts_dir)?;
    let mut engine = matexp::runtime::Engine::pjrt(&registry, cfg.variant)?;
    let arms = ablations::tile_sweep(&mut engine, &registry, n, cfg.seed)?;
    print!("{}", report::render_ablation(&format!("tiles (n={n})"), &arms));
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_ablation_tiles(_cfg: &MatexpConfig, _n: usize) -> Result<()> {
    Err(MatexpError::Config(
        "the tiles ablation sweeps PJRT artifacts; rebuild with `--features xla`".into(),
    ))
}

fn cmd_serve(args: &Args, cfg: MatexpConfig) -> Result<()> {
    let conn_threads: usize = args.get_parsed_or("conn-threads", 16)?;
    args.reject_unknown()?;
    let addr = cfg.server_addr.clone();
    println!(
        "starting coordinator: {} workers, backend {}",
        cfg.workers, cfg.backend,
    );
    let service = Arc::new(Service::start(cfg)?);
    if service.sizes().is_empty() {
        println!("serving any matrix size (size-agnostic backend)");
    } else {
        println!("serving sizes {:?}", service.sizes());
    }
    matexp::server::server::serve(service, &addr, conn_threads)
}

/// `matexp route` — run the cluster router: one listening socket speaking
/// the full wire protocol, fanning expm work out to the member servers by
/// content affinity (see [`matexp::cluster`]).
fn cmd_route(args: &Args, mut cfg: MatexpConfig) -> Result<()> {
    let conn_threads: usize = args.get_parsed_or("conn-threads", 16)?;
    if let Some(list) = args.get("members") {
        cfg.cluster.members =
            list.split(',').map(str::trim).filter(|m| !m.is_empty()).map(String::from).collect();
    }
    if let Some(k) = args.get_parsed::<usize>("shed-at")? {
        cfg.cluster.shed_at = k;
    }
    if let Some(ms) = args.get_parsed::<u64>("health-ms")? {
        cfg.cluster.health_ms = ms;
    }
    args.reject_unknown()?;
    cfg.validate()?;
    if cfg.cluster.members.is_empty() {
        return Err(MatexpError::Config(
            "route needs at least one member (--members HOST:PORT,… or cluster.members)".into(),
        ));
    }
    let router = matexp::cluster::Router::start(&cfg.server_addr, &cfg.cluster, conn_threads)?;
    println!(
        "matexp routing on {} over {} members (shed-at {}, health every {} ms)",
        router.local_addr(),
        cfg.cluster.members.len(),
        cfg.cluster.shed_at,
        cfg.cluster.health_ms,
    );
    router.join();
    Ok(())
}

/// `matexp trace` — pull a running server's flight recorder and emit it
/// as a Chrome trace-event document (Perfetto / `chrome://tracing`).
fn cmd_trace(args: &Args, cfg: &MatexpConfig) -> Result<()> {
    let check = args.has("check");
    let out = args.get("out").map(str::to_string);
    args.reject_unknown()?;
    let mut client = MatexpClient::connect(&cfg.server_addr)?;
    let doc = client.trace_dump()?;
    if check {
        let events = matexp::trace::chrome::validate(&doc)?;
        println!("valid Chrome trace: {events} events");
    }
    let text = doc.to_string_pretty();
    match out {
        Some(path) => {
            std::fs::write(&path, text + "\n")?;
            println!("trace written to {path} (load it in https://ui.perfetto.dev)");
        }
        None if check => {} // --check alone validates without dumping
        None => println!("{text}"),
    }
    Ok(())
}

/// `matexp metrics` — fetch a running server's metrics snapshot, as JSON
/// or Prometheus text exposition.
fn cmd_metrics(args: &Args, cfg: &MatexpConfig) -> Result<()> {
    let format = args.get_or("format", "json");
    args.reject_unknown()?;
    let mut client = MatexpClient::connect(&cfg.server_addr)?;
    match format.as_str() {
        "json" => println!("{}", client.metrics()?.to_string_pretty()),
        "prometheus" => print!("{}", client.metrics_prometheus()?),
        other => {
            return Err(MatexpError::Config(format!(
                "unknown metrics format {other:?} (json|prometheus)"
            )))
        }
    }
    Ok(())
}

fn cmd_loadtest(args: &Args, cfg: MatexpConfig) -> Result<()> {
    // validation-only mode: CI gates committed `BENCH_*.json` files on it
    if let Some(path) = args.get("check") {
        let path = path.to_string();
        args.reject_unknown()?;
        let text = std::fs::read_to_string(&path)?;
        let v = matexp::util::json::Json::parse(&text)?;
        loadtest::validate_snapshot(&v)?;
        println!("{path}: valid loadtest snapshot");
        return Ok(());
    }

    let lt = LoadtestConfig {
        clients: args.get_parsed_or("clients", 4)?,
        requests: args.get_parsed_or("requests", 25)?,
        warmup: args.get_parsed_or("warmup", 2)?,
        n: args.get_parsed_or("n", 64)?,
        power: args.get_parsed_or("power", 256)?,
        method: Method::from_str(&args.get_or("method", "ours"))?,
        rate: args.get_parsed::<f64>("rate")?,
        seed: cfg.seed,
    };
    lt.validate()?;
    let modes: Vec<WireMode> = match args.get_or("wire", "all").as_str() {
        "all" => WireMode::all().to_vec(),
        one => vec![WireMode::from_str(one)?],
    };
    let codec_n: usize = args.get_parsed_or("codec-n", 1024)?;
    let bench_id: u64 = args.get_parsed_or("bench-id", 9)?;
    let out = args.get_or("out", &format!("BENCH_{bench_id}.json"));
    let external_addr = args.get("addr").map(str::to_string);
    args.reject_unknown()?;

    // no --addr: serve ourselves in-process so `matexp loadtest` is a
    // one-command benchmark (and the CI smoke job needs no orchestration)
    let (addr, own_server) = match external_addr {
        Some(addr) => (addr, None),
        None => {
            println!("starting in-process server: {} workers, backend {}", cfg.workers, cfg.backend);
            let service = Arc::new(Service::start(cfg)?);
            let server =
                matexp::server::server::serve_background(Arc::clone(&service), "127.0.0.1:0", 32)?;
            (server.local_addr().to_string(), Some((service, server)))
        }
    };

    let mut reports = Vec::with_capacity(modes.len());
    for mode in modes {
        println!(
            "{}: {} clients x {} requests (+{} warmup), n={}, N={} ({} loop)…",
            mode.as_str(),
            lt.clients,
            lt.requests,
            lt.warmup,
            lt.n,
            lt.power,
            if lt.rate.is_some() { "open" } else { "closed" },
        );
        reports.push(loadtest::run_mode(&addr, mode, &lt)?);
    }
    let codec = loadtest::codec_roundtrip(codec_n, 3);
    print!("\n{}", loadtest::render(&reports, &codec));

    // against a router, the status op yields per-member routed counts —
    // the snapshot's affinity evidence; a plain server yields none
    let members = loadtest::fetch_members(&addr);
    let snap = loadtest::snapshot(bench_id, &lt, &reports, &codec, &members);
    loadtest::validate_snapshot(&snap)?;
    std::fs::write(&out, snap.to_string_pretty() + "\n")?;
    println!("snapshot written to {out}");

    if let Some((_service, server)) = own_server {
        server.shutdown(); // unblocks accept, drains connections, joins threads
    }
    Ok(())
}

fn cmd_bench_report(args: &Args, cfg: &MatexpConfig) -> Result<()> {
    args.reject_unknown()?;
    for id in 2..=5u8 {
        let t = experiments::run_table_sim(id, cfg)?;
        print!("{}", report::render_table(&t));
        println!();
    }
    Ok(())
}
