//! Standard base64 (RFC 4648, with padding) — in-tree substrate for the
//! wire protocol's binary matrix encoding.

const ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes to standard base64 with `=` padding.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(triple >> 18) as usize & 63] as char);
        out.push(ALPHABET[(triple >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(triple >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[triple as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

/// Decode standard base64 (padding required, no whitespace).
pub fn decode(text: &str) -> Option<Vec<u8>> {
    let bytes = text.as_bytes();
    if bytes.len() % 4 != 0 {
        return None;
    }
    // inverse table
    let mut inv = [255u8; 256];
    for (i, &c) in ALPHABET.iter().enumerate() {
        inv[c as usize] = i as u8;
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for chunk in bytes.chunks(4) {
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && chunk[3] != b'=') || (pad == 2 && chunk[2] != b'=') {
            return None;
        }
        let mut triple = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            let v = if c == b'=' {
                if i < 2 {
                    return None; // '=' only in the last two positions
                }
                0
            } else {
                let v = inv[c as usize];
                if v == 255 {
                    return None;
                }
                v as u32
            };
            triple = (triple << 6) | v;
        }
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Some(out)
}

/// `f32` slice → base64 of its little-endian bytes.
pub fn encode_f32(data: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    encode(&bytes)
}

/// base64 of little-endian `f32` bytes → values.
pub fn decode_f32(text: &str) -> Option<Vec<f32>> {
    let bytes = decode(text)?;
    if bytes.len() % 4 != 0 {
        return None;
    }
    Some(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn binary_roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["A", "AB=C", "====", "A?==", "Zg==Zg==X"] {
            assert!(decode(bad).is_none(), "{bad:?}");
        }
    }

    #[test]
    fn f32_roundtrip_exact() {
        let data = vec![0.1f32, -3.25, f32::MIN_POSITIVE, 1e30, 0.0, -0.0];
        let enc = encode_f32(&data);
        let back = decode_f32(&enc).unwrap();
        assert_eq!(data.len(), back.len());
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact roundtrip");
        }
    }

    #[test]
    fn f32_decode_rejects_ragged() {
        assert!(decode_f32("Zg==").is_none()); // 1 byte, not a multiple of 4
    }
}
