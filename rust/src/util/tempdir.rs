//! Self-cleaning temporary directories for tests (in-tree replacement for
//! the `tempfile` crate, which the offline vendor set lacks).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely-named directory under the system temp dir, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `$TMPDIR/matexp-<pid>-<seq>`.
    pub fn new() -> std::io::Result<TempDir> {
        let seq = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "matexp-{}-{}",
            std::process::id(),
            seq
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory's path (removed recursively on drop).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Path of a file inside the directory (not created).
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept_path;
        {
            let d = TempDir::new().unwrap();
            kept_path = d.path().to_path_buf();
            assert!(kept_path.is_dir());
            std::fs::write(d.file("x.txt"), b"hello").unwrap();
            assert!(d.file("x.txt").exists());
        }
        assert!(!kept_path.exists(), "dropped dir should be removed");
    }

    #[test]
    fn two_dirs_are_distinct() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
