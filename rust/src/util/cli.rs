//! Tiny CLI argument parser (in-tree replacement for `clap`).
//!
//! Supports the subcommand + flags shape `matexp` uses:
//! `matexp <command> [--flag value] [--switch] [positional…]`.
//! Flags accept both `--flag value` and `--flag=value`.

use std::collections::BTreeMap;

use crate::error::{MatexpError, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` pairs, and `--switch` as `"true"`.
    flags: BTreeMap<String, String>,
    /// Non-flag tokens after the command.
    pub positional: Vec<String>,
    /// Flag names that were consumed via accessors (for unknown-flag checks).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `std::env::args().skip(1)`-style tokens.
    ///
    /// Every `--name` token is a flag. If the *next* token exists and does
    /// not start with `--`, it is that flag's value; otherwise the flag is
    /// a boolean switch. This is unambiguous for our CLI because no
    /// positional argument follows a switch.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(MatexpError::Config("bare `--` not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(name.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    fn mark(&self, name: &str) {
        self.seen.borrow_mut().push(name.to_string());
    }

    /// String flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Boolean switch (present without value, or `--flag true/false`).
    pub fn has(&self, name: &str) -> bool {
        self.mark(name);
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse a flag value with a typed error message.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| {
                MatexpError::Config(format!("--{name}: cannot parse {v:?}"))
            }),
        }
    }

    /// Typed flag with default.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    /// Error on any flag never consumed by an accessor — catches typos.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                return Err(MatexpError::Config(format!("unknown flag --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn command_flags_positionals() {
        let a = parse("experiment --table 2 --variant xla extra");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.get("table"), Some("2"));
        assert_eq!(a.get("variant"), Some("xla"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("plan --power=512 --fused");
        assert_eq!(a.get_parsed::<u64>("power").unwrap(), Some(512));
        assert!(a.has("fused"));
    }

    #[test]
    fn switch_at_end_and_before_flag() {
        let a = parse("serve --quiet --addr 0.0.0.0:7070");
        assert!(a.has("quiet"));
        assert_eq!(a.get("addr"), Some("0.0.0.0:7070"));
    }

    #[test]
    fn typed_parse_errors() {
        let a = parse("x --n abc");
        assert!(a.get_parsed::<usize>("n").is_err());
        assert_eq!(a.get_parsed_or("missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.reject_unknown().is_err());
        let _ = a.get("typo");
        a.reject_unknown().unwrap();
    }

    #[test]
    fn no_command() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.has("help"));
    }
}
