//! In-tree substrates replacing external crates the offline vendor set
//! lacks: JSON (`serde_json`), CLI parsing (`clap`), thread pool /
//! fork-join (`rayon`/`tokio`), property testing (`proptest`), and temp
//! dirs (`tempfile`).

pub mod base64;
pub mod cli;
pub mod json;
pub mod prop;
pub mod tempdir;
pub mod threadpool;
