//! Miniature property-based testing harness (in-tree `proptest`
//! replacement for the offline build).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for a
//! configurable number of deterministic cases. On failure it *shrinks*:
//! every generated integer is re-tried at smaller values (halving toward
//! the generator's minimum) while the rest of the case is replayed
//! verbatim, and the smallest still-failing case is reported.
//!
//! ```no_run
//! use matexp::util::prop::{property, Gen};
//! property("addition commutes", 256, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! (`no_run` to keep doctest time down; the same property runs compiled
//! in this module's unit tests.)

use crate::linalg::rand::XorShift64;

/// Per-case value source. Records every draw so the runner can replay and
/// shrink a failing case.
pub struct Gen {
    rng: XorShift64,
    /// (min, drawn) for every integer draw, in draw order.
    trace: Vec<(u64, u64)>,
    /// When replaying/shrinking: overrides for the first `k` draws.
    replay: Vec<u64>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64, replay: Vec<u64>) -> Gen {
        Gen { rng: XorShift64::new(seed), trace: Vec::new(), replay, cursor: 0 }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "empty range");
        let fresh = lo + self.rng.next_below(hi - lo + 1);
        let v = match self.replay.get(self.cursor) {
            Some(&forced) => forced.clamp(lo, hi),
            None => fresh,
        };
        self.cursor += 1;
        self.trace.push((lo, v));
        v
    }

    /// Uniform integer in `[lo, hi]` (inclusive), as `usize`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f32 in `[-scale, scale)`, derived from an integer draw so
    /// it shrinks toward 0.
    pub fn f32(&mut self, scale: f32) -> f32 {
        let raw = self.u64(0, 1 << 24);
        (raw as f32 / (1u64 << 23) as f32 - 1.0) * scale
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.usize(0, items.len() - 1)]
    }

    /// A fair coin flip (shrinks toward `false`).
    pub fn bool(&mut self) -> bool {
        self.u64(0, 1) == 1
    }
}

/// Run `cases` deterministic cases of `prop`; panic with the smallest
/// shrunk counterexample on failure.
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 ^ (case.wrapping_mul(0x9E37_79B9));
        let outcome = run_one(&prop, seed, Vec::new());
        if let Err((msg, trace)) = outcome {
            let (shrunk_trace, shrunk_msg) = shrink(&prop, seed, trace, msg);
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x})\n\
                 shrunk draws: {shrunk_trace:?}\npanic: {shrunk_msg}"
            );
        }
    }
}

type Failure = (String, Vec<(u64, u64)>);

fn run_one<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    seed: u64,
    replay: Vec<u64>,
) -> std::result::Result<(), Failure> {
    let mut g = Gen::new(seed, replay);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
    match result {
        Ok(()) => Ok(()),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            Err((msg, g.trace))
        }
    }
}

/// Shrink each drawn integer to the smallest value that still fails,
/// by per-draw binary search (with the other draws replayed verbatim).
/// Bounded passes, so always terminates.
fn shrink<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    seed: u64,
    mut trace: Vec<(u64, u64)>,
    mut msg: String,
) -> (Vec<u64>, String) {
    // suppress the panic spew from shrink probes
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    for _pass in 0..4 {
        let mut improved = false;
        for i in 0..trace.len() {
            let (lo, cur) = trace[i];
            if cur == lo {
                continue;
            }
            let probe = |cand: u64, trace: &[(u64, u64)]| -> Option<Failure> {
                let mut replay: Vec<u64> = trace.iter().map(|&(_, v)| v).collect();
                replay[i] = cand;
                run_one(prop, seed, replay).err()
            };
            // fast path: the minimum itself still fails
            if let Some((new_msg, new_trace)) = probe(lo, &trace) {
                trace = new_trace;
                msg = new_msg;
                improved = true;
                continue;
            }
            // binary search the boundary: `ok` passes, `fail` fails
            let mut ok = lo;
            let mut fail = cur;
            let mut best: Option<Failure> = None;
            while fail - ok > 1 {
                let mid = ok + (fail - ok) / 2;
                match probe(mid, &trace) {
                    Some(f) => {
                        fail = mid;
                        best = Some(f);
                    }
                    None => ok = mid,
                }
            }
            if let Some((new_msg, new_trace)) = best {
                if fail < cur {
                    trace = new_trace;
                    msg = new_msg;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    std::panic::set_hook(prev_hook);
    (trace.iter().map(|&(_, v)| v).collect(), msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        property("sum symmetric", 64, |g| {
            let a = g.u64(0, 100);
            let b = g.u64(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_panics_and_shrinks() {
        let result = std::panic::catch_unwind(|| {
            property("find big", 256, |g| {
                let x = g.u64(0, 1000);
                assert!(x < 500, "x too big: {x}");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // shrinker should walk x down to exactly the boundary 500
        assert!(msg.contains("[500]"), "unshrunk: {msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        property("ranges", 64, |g| {
            let v = g.u64(10, 20);
            assert!((10..=20).contains(&v));
            let f = g.f32(2.0);
            assert!((-2.0..2.0).contains(&f), "{f}");
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        let mut g = Gen::new(7, Vec::new());
        for _ in 0..10 {
            first.push(g.u64(0, 1_000_000));
        }
        let mut g = Gen::new(7, Vec::new());
        for v in &first {
            assert_eq!(g.u64(0, 1_000_000), *v);
        }
    }
}
