//! Minimal JSON substrate — parser, value model, and serializer.
//!
//! The build is fully offline (crates resolve from a vendored registry
//! that lacks `serde`/`serde_json`), so the manifest reader, the config
//! loader and the TCP wire protocol run on this in-tree implementation.
//! It supports exactly what those call sites need: the full JSON value
//! model, strict parsing with byte-offset errors, escape handling, and a
//! compact writer with a fast path for large `f32` arrays (the wire
//! protocol ships whole matrices).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects preserve no insertion order (BTreeMap) —
/// deterministic output matters more than order fidelity here.
///
/// Arrays consisting purely of numbers parse into the packed
/// [`Json::NumArr`] — matrix payloads are 262k elements at n=512, and
/// boxing each into a `Json` costs ~20 ms per request. `NumArr` and an
/// element-wise-equal `Arr` compare equal (see the manual `PartialEq`).
#[derive(Clone, Debug)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// A boxed (mixed-type) array.
    Arr(Vec<Json>),
    /// Packed all-numeric array (matrix payloads).
    NumArr(Vec<f64>),
    /// An object (sorted keys — deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            (Json::NumArr(a), Json::NumArr(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            // packed and boxed numeric arrays are the same JSON document
            (Json::NumArr(a), Json::Arr(b)) | (Json::Arr(b), Json::NumArr(a)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, j)| j.as_f64() == Some(*x))
            }
            _ => false,
        }
    }
}

/// Parse error with the byte offset where parsing failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset where parsing failed.
    pub offset: usize,
    /// What went wrong there.
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Result alias over [`JsonError`].
pub type JsonResult<T> = std::result::Result<T, JsonError>;

// ---------------------------------------------------------------- access

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> JsonResult<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// The boolean, for `Bool` values.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The number, for `Num` values.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, when it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// [`Json::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// The string, for `Str` values.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Generic (boxed) array access. All-numeric arrays parse as
    /// [`Json::NumArr`] — use [`Json::as_f32_vec`] / [`Json::as_usize_vec`]
    /// / [`Json::arr_len`] for those.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Element count of either array representation.
    pub fn arr_len(&self) -> Option<usize> {
        match self {
            Json::Arr(v) => Some(v.len()),
            Json::NumArr(v) => Some(v.len()),
            _ => None,
        }
    }

    /// Numeric array → `Vec<usize>` (e.g. the manifest's `blocks` field).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        match self {
            Json::NumArr(v) => v
                .iter()
                .map(|&x| {
                    if x >= 0.0 && x.fract() == 0.0 {
                        Some(x as usize)
                    } else {
                        None
                    }
                })
                .collect(),
            Json::Arr(v) => v.iter().map(Json::as_usize).collect(),
            _ => None,
        }
    }

    /// The key→value map, for `Obj` values.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `true` for the `Null` value.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Numeric array → `Vec<f32>` (the wire matrix payload).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        match self {
            Json::NumArr(v) => Some(v.iter().map(|&x| x as f32).collect()),
            Json::Arr(arr) => {
                let mut out = Vec::with_capacity(arr.len());
                for v in arr {
                    out.push(v.as_f64()? as f32);
                }
                Some(out)
            }
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty serialization (2-space indent) — config files, reports.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::NumArr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_num(*x, out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    e.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

/// Builder conveniences so call sites read like literals.
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// `obj![("k", v), ...]` — ordered object construction.
#[macro_export]
macro_rules! json_obj {
    ($(($k:expr, $v:expr)),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::util::json::Json::from($v)); )*
        $crate::util::json::Json::Obj(m)
    }};
}

/// `f32` slice → JSON array string, appended directly (fast path for
/// matrix payloads: avoids building a `Vec<Json>` of 256k elements).
///
/// Numbers are formatted as *f32* shortest round-trip — going through f64
/// emits up to 17 digits for what is exactly representable in 9
/// (`0.1f32` → `"0.10000000149011612"`), which costs 2.4x the bytes and
/// most of the encode time. Every finite value (subnormals included)
/// reparses bit-exactly; a proptest holds this invariant.
///
/// JSON has no NaN/±Inf, so a non-finite element is a **typed error**
/// (`out` is rolled back to its original length) — callers either
/// guarantee finiteness or surface the error (the wire layer reports it
/// as a protocol error rather than silently corrupting the payload, which
/// is what the old `null`-emitting behavior did).
pub fn write_f32_array(data: &[f32], out: &mut String) -> JsonResult<()> {
    let rollback = out.len();
    out.reserve(data.len() * 12 + 2);
    out.push('[');
    for (i, v) in data.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let v = *v;
        if !v.is_finite() {
            out.truncate(rollback);
            return Err(JsonError {
                offset: i,
                message: format!(
                    "element {i} is {v}: NaN/Inf are not representable in JSON \
                     (use the base64 payload for non-finite matrices)"
                ),
            });
        } else if v == 0.0 && v.is_sign_negative() {
            // `0.0 as i64` would drop the sign; "-0" reparses bit-exactly
            out.push_str("-0");
        } else if v == v.trunc() && v.abs() < 1e7 {
            let _ = write!(out, "{}", v as i64);
        } else {
            let _ = write!(out, "{v}");
        }
    }
    out.push(']');
    Ok(())
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; encode as null (callers validate finiteness
        // before serializing matrices).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        // shortest roundtrip repr rust gives us
        let _ = write!(out, "{x}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> JsonResult<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> JsonResult<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> JsonResult<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word:?}")))
        }
    }

    fn object(&mut self) -> JsonResult<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> JsonResult<Json> {
        self.expect(b'[')?;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(Vec::new()));
        }
        // fast path: run of plain numbers (matrix payloads) — parsed into
        // a packed Vec<f64> with no per-element Json boxing
        let mut nums: Vec<f64> = Vec::new();
        loop {
            self.skip_ws();
            match self.peek() {
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    nums.push(self.raw_number()?);
                }
                _ => break, // non-number element: fall back to generic
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::NumArr(nums));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
        // generic path, seeded with whatever the fast path consumed
        let mut v: Vec<Json> = nums.into_iter().map(Json::Num).collect();
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> JsonResult<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // bulk-consume the run up to the next quote/escape/control
                    // byte and validate it as UTF-8 once — validating from
                    // the cursor per character is O(n²) and turns a 1.4 MB
                    // base64 payload into a 30 s parse
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        self.pos += 1;
                    }
                    let run = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(run);
                }
            }
        }
    }

    fn hex4(&mut self) -> JsonResult<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> JsonResult<Json> {
        self.raw_number().map(Json::Num)
    }

    fn raw_number(&mut self) -> JsonResult<f64> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map_err(|_| JsonError { offset: start, message: format!("bad number {text:?}") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ and unicode: ü 中 \u{1F600}";
        let v = Json::Str(s.into());
        let encoded = v.to_string();
        assert_eq!(Json::parse(&encoded).unwrap(), v);
    }

    #[test]
    fn unicode_escapes_parse() {
        assert_eq!(Json::parse(r#""ü""#).unwrap(), Json::Str("ü".into()));
        // surrogate pair: 😀
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "nul", "1 2", "{\"a\":1,}", "[1,]"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn error_carries_offset() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::parse(r#"{"z": 1, "a": [true, null, 2.5], "s": "x"}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn numbers_render_integers_cleanly() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn u64_accessor_bounds() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(-3.0).as_u64(), None);
        assert_eq!(Json::Num(3.5).as_u64(), None);
    }

    #[test]
    fn f32_vec_payload() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(Json::parse(r#"[1, "x"]"#).unwrap().as_f32_vec().is_none());
    }

    #[test]
    fn write_f32_array_fast_path() {
        let mut s = String::new();
        write_f32_array(&[1.0, -0.5, 3.25], &mut s).unwrap();
        assert_eq!(s, "[1,-0.5,3.25]");
        assert_eq!(
            Json::parse(&s).unwrap().as_f32_vec().unwrap(),
            vec![1.0, -0.5, 3.25]
        );
    }

    #[test]
    fn write_f32_array_rejects_non_finite_and_rolls_back() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut s = String::from("prefix:");
            let err = write_f32_array(&[1.0, bad], &mut s).unwrap_err();
            assert!(err.message.contains("not representable"), "{err}");
            assert_eq!(s, "prefix:", "failed encode must not leave partial output");
        }
    }

    fn roundtrip_bits(vals: &[f32]) -> Vec<u32> {
        let mut s = String::new();
        write_f32_array(vals, &mut s).unwrap();
        Json::parse(&s)
            .unwrap()
            .as_f32_vec()
            .unwrap()
            .iter()
            .map(|v| v.to_bits())
            .collect()
    }

    #[test]
    fn write_f32_array_subnormals_and_edges_roundtrip_bit_exactly() {
        let edges = [
            0.0f32,
            -0.0,
            f32::MIN_POSITIVE,               // smallest normal
            f32::MIN_POSITIVE / 2.0,         // subnormal
            f32::from_bits(1),               // smallest subnormal (1.4e-45)
            f32::from_bits(0x8000_0001),     // smallest negative subnormal
            f32::MAX,
            f32::MIN,
            1e7,                             // just past the integer fast path
            9_999_999.0,
            -9_999_999.0,
            0.1,
            std::f32::consts::PI,
        ];
        let want: Vec<u32> = edges.iter().map(|v| v.to_bits()).collect();
        assert_eq!(roundtrip_bits(&edges), want);
    }

    #[test]
    fn prop_f32_arrays_reparse_bit_exactly() {
        use crate::util::prop::property;
        // arbitrary finite bit patterns — subnormals, -0.0 and extreme
        // exponents included — must survive the wire bit-for-bit
        property("write_f32_array roundtrips bit-exactly", 192, |g| {
            let len = g.usize(0, 12);
            let vals: Vec<f32> = (0..len)
                .map(|_| loop {
                    let v = f32::from_bits(g.u64(0, u32::MAX as u64) as u32);
                    if v.is_finite() {
                        break v;
                    }
                })
                .collect();
            let want: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
            assert_eq!(roundtrip_bits(&vals), want, "vals {vals:?}");
        });
    }

    #[test]
    fn obj_macro_builds_objects() {
        let v = json_obj![("a", 1u64), ("b", "x")];
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn deep_nesting_parses() {
        let depth = 200;
        let doc = "[".repeat(depth) + &"]".repeat(depth);
        assert!(Json::parse(&doc).is_ok());
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
    }
}
