//! Fixed-size thread pool (in-tree `rayon`/tokio-executor replacement).
//!
//! Two services on top of one primitive:
//! * [`ThreadPool`] — long-lived pool executing boxed jobs (the TCP
//!   server's per-connection handler).
//! * [`parallel_rows`] — scoped fork-join over row chunks (the threaded
//!   CPU matmul), using `std::thread::scope` so borrows need no `'static`.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers (≥ 1 enforced).
    pub fn new(threads: usize, name: &str) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Enqueue a job; runs on some worker thread.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("pool workers alive");
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        let job = {
            let guard = rx.lock().expect("pool queue poisoned");
            guard.recv()
        };
        match job {
            Ok(job) => job(),
            Err(_) => return, // sender dropped: shutdown
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped fork-join: split `out` into contiguous row chunks of `row_len`
/// and run `f(first_row_index, chunk)` on up to `threads` OS threads.
///
/// Chunks are disjoint `&mut` slices, so no synchronization is needed —
/// the same shape as rayon's `par_chunks_mut().enumerate()`.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0, "row_len must be positive");
    assert_eq!(out.len() % row_len, 0, "out must be whole rows");
    let n_rows = out.len() / row_len;
    let threads = threads.max(1).min(n_rows.max(1));
    let rows_per = n_rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0;
        let f = &f;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let first = row0;
            scope.spawn(move || {
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    f(first + i, row);
                }
            });
            row0 += take / row_len;
            rest = tail;
        }
    });
}

/// Default parallelism: available CPUs (min 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let counter = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                counter.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2, "drop-test");
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&flag);
        pool.execute(move || {
            f2.fetch_add(1, Ordering::Relaxed);
        });
        drop(pool); // must not hang, must run the queued job first
        assert_eq!(flag.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_rows_covers_every_row() {
        let n = 37; // deliberately not divisible by thread count
        let mut data = vec![0.0f32; n * 8];
        parallel_rows(&mut data, 8, 4, |row, chunk| {
            for v in chunk.iter_mut() {
                *v = row as f32;
            }
        });
        for (i, row) in data.chunks(8).enumerate() {
            assert!(row.iter().all(|&v| v == i as f32), "row {i}");
        }
    }

    #[test]
    fn parallel_rows_single_thread_and_empty() {
        let mut data = vec![0.0f32; 4];
        parallel_rows(&mut data, 4, 1, |_, chunk| chunk[0] = 1.0);
        assert_eq!(data[0], 1.0);
        let mut empty: Vec<f32> = Vec::new();
        parallel_rows(&mut empty, 4, 4, |_, _| panic!("no rows"));
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        let mut data = vec![0.0f32; 10];
        parallel_rows(&mut data, 4, 2, |_, _| {});
    }
}
