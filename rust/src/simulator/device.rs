//! Device specifications — Table 1 of the paper, as data.

/// Static description of a compute device.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    /// Marketing name ("NVIDIA Tesla C2050").
    pub name: String,
    /// Streaming multiprocessors ("Number of Processors" in Table 1).
    pub processors: u32,
    /// Total cores.
    pub cores: u32,
    /// Cores per streaming multiprocessor.
    pub cores_per_processor: u32,
    /// Shader clock, MHz.
    pub clock_mhz: u32,
    /// Core (graphics) clock, MHz.
    pub core_clock_mhz: u32,
    /// Device memory bandwidth, GB/s.
    pub bandwidth_gbs: f64,
    /// Memory bus type ("GDDR5" in Table 1).
    pub bus_type: String,
    /// Peak single-precision GFLOP/s as reported by the vendor/paper.
    pub peak_gflops: f64,
    /// Host↔device interconnect bandwidth, GB/s (PCIe 2.0 x16 for 2012).
    pub pcie_gbs: f64,
}

impl DeviceSpec {
    /// NVIDIA Tesla C2050 — Table 1 verbatim (plus the PCIe 2.0 x16 link
    /// the card shipped on, which Table 1 omits).
    pub fn tesla_c2050() -> DeviceSpec {
        DeviceSpec {
            name: "NVIDIA Tesla C2050".into(),
            processors: 14,
            cores: 448,
            cores_per_processor: 32,
            clock_mhz: 1150,
            core_clock_mhz: 575,
            bandwidth_gbs: 144.0,
            bus_type: "GDDR5".into(),
            peak_gflops: 1288.0,
            pcie_gbs: 8.0,
        }
    }

    /// The paper's host: 16-core Intel Xeon @ 2.40 GHz, 8 GB RAM.
    /// `peak_gflops` is a *single core's* scalar-ish throughput, because
    /// the paper's CPU baseline is sequential (§4.1).
    pub fn xeon_2012_single_core() -> DeviceSpec {
        DeviceSpec {
            name: "Intel Xeon 2.40GHz (1 core, sequential baseline)".into(),
            processors: 1,
            cores: 1,
            cores_per_processor: 1,
            clock_mhz: 2400,
            core_clock_mhz: 2400,
            bandwidth_gbs: 25.6,
            bus_type: "DDR3".into(),
            // ~1 flop/cycle sustained for an unblocked triple loop
            peak_gflops: 2.4,
            pcie_gbs: f64::INFINITY,
        }
    }

    /// Render the spec as the paper's Table 1 rows.
    pub fn table1_rows(&self) -> Vec<(String, String)> {
        vec![
            ("Model of GPU".into(), self.name.clone()),
            ("Number of Processors".into(), self.processors.to_string()),
            ("Number of cores".into(), self.cores.to_string()),
            ("Number of cores per Processor".into(), self.cores_per_processor.to_string()),
            ("Clock Frequency".into(), format!("{} (in MHz)", self.clock_mhz)),
            ("Core clock Frequency".into(), format!("{} (in MHz)", self.core_clock_mhz)),
            ("Bandwidth".into(), format!("{} (GBs/Sec)", self.bandwidth_gbs)),
            ("Bus Type".into(), self.bus_type.clone()),
            ("Processing Power max in GFLOPs".into(), format!("{}", self.peak_gflops)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c2050_matches_paper_table1() {
        let d = DeviceSpec::tesla_c2050();
        assert_eq!(d.processors, 14);
        assert_eq!(d.cores, 448);
        assert_eq!(d.cores_per_processor, 32);
        assert_eq!(d.clock_mhz, 1150);
        assert_eq!(d.core_clock_mhz, 575);
        assert_eq!(d.bandwidth_gbs, 144.0);
        assert_eq!(d.peak_gflops, 1288.0);
        // internal consistency: cores = processors * cores_per_processor
        assert_eq!(d.cores, d.processors * d.cores_per_processor);
    }

    #[test]
    fn table1_renders_all_rows() {
        let rows = DeviceSpec::tesla_c2050().table1_rows();
        assert_eq!(rows.len(), 9);
        assert!(rows.iter().any(|(k, v)| k == "Bus Type" && v == "GDDR5"));
    }

    #[test]
    fn xeon_baseline_is_single_core() {
        let d = DeviceSpec::xeon_2012_single_core();
        assert_eq!(d.cores, 1);
        assert!(d.peak_gflops < 10.0, "sequential baseline, not the whole socket");
    }
}
