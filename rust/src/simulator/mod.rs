//! Analytic timing model of the paper's 2012 testbed.
//!
//! We do not have a Tesla C2050 or its OpenCL stack (repro band 0/5), so
//! absolute GPU wall-clock is *simulated*: an analytic per-launch cost
//! model (fixed launch overhead + PCIe transfer + roofline kernel time)
//! whose three coefficients are least-squares calibrated against the
//! paper's own naive-GPU columns ([`calibrate`]). The simulator then
//! *predicts* every other cell of Tables 2–5, which the experiment harness
//! prints next to the paper's numbers and our measured CPU-PJRT numbers —
//! making the claim structure ("who wins, by what factor") checkable on
//! this testbed. See DESIGN.md §6.

pub mod calibrate;
pub mod device;
pub mod timing;

pub use device::DeviceSpec;
pub use timing::{GpuTimingModel, SimReport};
