//! Least-squares calibration of the timing model against published data.
//!
//! The naive-GPU column of each paper table gives observations
//! `t(n, N) = L · (a + b·s_bytes + c·s_flops)` with `L = N − 1` launches,
//! `s_bytes = 3·4n²` (per-launch PCIe traffic) and `s_flops = 2n³`.
//! Dividing by `L` yields a plain linear model in `(1, s_bytes, s_flops)`
//! that we fit by normal equations. `a → launch_overhead_s`,
//! `1/b → eff_pcie_bytes_per_s`, `1/c → eff_flops`.

use crate::simulator::device::DeviceSpec;
use crate::simulator::timing::GpuTimingModel;

/// One published observation: naive-GPU wall time for (n, power).
#[derive(Clone, Copy, Debug)]
pub struct Observation {
    /// Matrix side length.
    pub n: usize,
    /// Exponent `N` of the observed run.
    pub power: u64,
    /// Published wall-clock seconds for the naive-GPU run.
    pub seconds: f64,
}

/// Solve a 3x3 linear system by Gaussian elimination with partial pivoting.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // pivot — total_cmp, not partial_cmp().unwrap(): degenerate
        // observations (NaN seconds, zero-launch rows) can plant NaN in
        // the normal equations, and pivot selection must not panic on
        // them (NaN orders above every finite value under total order,
        // so a NaN column simply fails the singularity check or yields a
        // NaN solution the caller clamps)
        let piv = (col..3).max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))?;
        if a[piv][col].abs() < 1e-30 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut acc = b[row];
        for k in row + 1..3 {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    Some(x)
}

/// Fit `(overhead, 1/pcie, 1/flops)` to per-launch times by least squares.
///
/// Negative coefficients (possible when the data cannot identify a term —
/// e.g. all-small matrices) are clamped to a tiny positive epsilon so the
/// resulting model stays physical.
pub fn fit_naive_gpu(observations: &[Observation], device: DeviceSpec) -> GpuTimingModel {
    // normal equations: (XᵀX) w = Xᵀy over features (1, bytes, flops).
    // Rows are weighted by 1/per_launch² so the fit minimizes RELATIVE
    // error — unweighted least squares is dominated by the big n=512
    // cells and misses the small-matrix cells the paper's Table 2 is
    // about by 2x.
    let mut xtx = [[0.0f64; 3]; 3];
    let mut xty = [0.0f64; 3];
    for obs in observations {
        let launches = (obs.power - 1) as f64;
        if launches <= 0.0 {
            continue;
        }
        let per_launch = obs.seconds / launches;
        // non-finite rows (NaN/inf seconds) must not poison the normal
        // equations — one bad observation would wipe out every valid one
        if !per_launch.is_finite() || per_launch <= 0.0 {
            continue;
        }
        let w = 1.0 / per_launch;
        let feat = [
            1.0,
            3.0 * (obs.n * obs.n * 4) as f64,
            2.0 * (obs.n as f64).powi(3),
        ];
        for i in 0..3 {
            for j in 0..3 {
                xtx[i][j] += w * feat[i] * feat[j];
            }
            xty[i] += w * feat[i] * per_launch;
        }
    }
    let base = GpuTimingModel::from_spec(device.clone());
    let Some(w) = solve3(xtx, xty) else {
        return base;
    };
    let overhead = w[0].max(1e-6);
    let pcie = if w[1] > 1e-18 { 1.0 / w[1] } else { base.eff_pcie_bytes_per_s };
    let flops = if w[2] > 1e-18 { 1.0 / w[2] } else { base.eff_flops };
    GpuTimingModel {
        device,
        launch_overhead_s: overhead,
        eff_pcie_bytes_per_s: pcie,
        eff_flops: flops,
        eff_mem_bytes_per_s: base.eff_mem_bytes_per_s,
        session_overhead_s: base.session_overhead_s,
        per_size_launch_s: base.per_size_launch_s,
    }
}

/// Per-size robust calibration: the geometric mean per-launch cost of the
/// published naive-GPU cells at each matrix size. Geometric (not
/// arithmetic) because the paper's per-launch costs at fixed n spread up
/// to 3.3x across powers (n=64: 0.8→2.6 ms/launch) and the multiplicative
/// middle minimizes worst-case *ratio* error.
pub fn fit_per_size(observations: &[Observation]) -> Vec<(usize, f64)> {
    use std::collections::BTreeMap;
    let mut logs: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    for obs in observations {
        if obs.power > 1 && obs.seconds > 0.0 {
            let per_launch = obs.seconds / (obs.power - 1) as f64;
            logs.entry(obs.n).or_default().push(per_launch.ln());
        }
    }
    logs.into_iter()
        .map(|(n, ls)| (n, (ls.iter().sum::<f64>() / ls.len() as f64).exp()))
        .collect()
}

/// Fit the per-invocation session overhead from published "Our Approach"
/// observations (device-resident binary plans): the mean positive residual
/// `t_paper − t_model` with the per-launch model already fixed.
pub fn fit_session_overhead(observations: &[Observation], model: &GpuTimingModel) -> f64 {
    let mut sum = 0.0;
    let mut count = 0usize;
    for obs in observations {
        let plan = crate::plan::Plan::binary(obs.power, false);
        let predicted = model.simulate_device_resident(&plan, obs.n).total_s;
        sum += obs.seconds - predicted;
        count += 1;
    }
    if count == 0 {
        return 0.0;
    }
    (sum / count as f64).max(0.0)
}

/// Fit the sequential-CPU effective GFLOP/s: one coefficient,
/// `t = multiplies · 2n³ / flops`.
pub fn fit_cpu_flops(observations: &[Observation]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for obs in observations {
        let work = 2.0 * (obs.n as f64).powi(3) * (obs.power - 1) as f64;
        // least squares for y = work / flops  =>  flops = Σwork² / Σ(work·y)
        num += work * work;
        den += work * obs.seconds;
    }
    if den <= 0.0 {
        2.4e9
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve3_known_system() {
        // x + y + z = 6; 2y + 5z = -4; 2x + 5y - z = 27 => (5, 3, -2)
        let a = [[1.0, 1.0, 1.0], [0.0, 2.0, 5.0], [2.0, 5.0, -1.0]];
        let b = [6.0, -4.0, 27.0];
        let x = solve3(a, b).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
        assert!((x[2] + 2.0).abs() < 1e-9);
    }

    #[test]
    fn solve3_singular_returns_none() {
        let a = [[1.0, 2.0, 3.0], [2.0, 4.0, 6.0], [0.0, 0.0, 1.0]];
        assert!(solve3(a, [1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn fit_recovers_synthetic_coefficients() {
        // generate observations from known (a, b, c), then recover them
        let (a, pcie, flops) = (2.5e-3, 4.8e9, 4.0e11);
        let mut obs = Vec::new();
        for n in [64usize, 128, 256, 512] {
            for power in [64u64, 128, 256, 512] {
                let per_launch =
                    a + 3.0 * (n * n * 4) as f64 / pcie + 2.0 * (n as f64).powi(3) / flops;
                obs.push(Observation { n, power, seconds: per_launch * (power - 1) as f64 });
            }
        }
        let m = fit_naive_gpu(&obs, DeviceSpec::tesla_c2050());
        assert!((m.launch_overhead_s - a).abs() / a < 1e-6, "{}", m.launch_overhead_s);
        assert!((m.eff_pcie_bytes_per_s - pcie).abs() / pcie < 1e-6);
        assert!((m.eff_flops - flops).abs() / flops < 1e-6);
    }

    #[test]
    fn fit_cpu_recovers_flops() {
        let flops = 2.4e9;
        let obs: Vec<Observation> = [64usize, 128, 256]
            .iter()
            .map(|&n| Observation {
                n,
                power: 64,
                seconds: 2.0 * (n as f64).powi(3) * 63.0 / flops,
            })
            .collect();
        let got = fit_cpu_flops(&obs);
        assert!((got - flops).abs() / flops < 1e-9, "{got}");
    }

    #[test]
    fn degenerate_data_falls_back_to_spec() {
        let m = fit_naive_gpu(&[], DeviceSpec::tesla_c2050());
        assert!(m.launch_overhead_s > 0.0);
        assert!(m.eff_flops > 0.0);
    }

    /// Regression: NaN observations used to panic in the pivot's
    /// `partial_cmp(..).unwrap()`. They must instead be skipped — an
    /// all-degenerate set falls back to the spec model, and a NaN mixed
    /// into good observations must not poison the fit of the good ones.
    #[test]
    fn nan_observations_do_not_panic_and_yield_a_physical_model() {
        let obs = [
            Observation { n: 64, power: 64, seconds: f64::NAN },
            Observation { n: 128, power: 128, seconds: f64::NAN },
            Observation { n: 256, power: 64, seconds: f64::NAN },
        ];
        let m = fit_naive_gpu(&obs, DeviceSpec::tesla_c2050());
        assert!(m.launch_overhead_s.is_finite() && m.launch_overhead_s > 0.0, "{m:?}");
        assert!(m.eff_pcie_bytes_per_s.is_finite() && m.eff_pcie_bytes_per_s > 0.0);
        assert!(m.eff_flops.is_finite() && m.eff_flops > 0.0);
        // solve3 itself survives NaN pivots (returns None or a NaN
        // solution, never panics)
        let nan_sys = [[f64::NAN; 3]; 3];
        let _ = solve3(nan_sys, [1.0, 2.0, 3.0]);
    }

    #[test]
    fn nan_observation_does_not_poison_good_ones() {
        // synthetic data from known coefficients, plus one NaN row: the
        // fit must still recover the coefficients from the good rows
        let (a, pcie, flops) = (2.5e-3, 4.8e9, 4.0e11);
        let mut obs = Vec::new();
        for n in [64usize, 128, 256, 512] {
            for power in [64u64, 128, 256, 512] {
                let per_launch =
                    a + 3.0 * (n * n * 4) as f64 / pcie + 2.0 * (n as f64).powi(3) / flops;
                obs.push(Observation { n, power, seconds: per_launch * (power - 1) as f64 });
            }
        }
        obs.push(Observation { n: 128, power: 256, seconds: f64::NAN });
        obs.push(Observation { n: 64, power: 64, seconds: f64::INFINITY });
        let m = fit_naive_gpu(&obs, DeviceSpec::tesla_c2050());
        assert!((m.launch_overhead_s - a).abs() / a < 1e-6, "{}", m.launch_overhead_s);
        assert!((m.eff_pcie_bytes_per_s - pcie).abs() / pcie < 1e-6);
        assert!((m.eff_flops - flops).abs() / flops < 1e-6);
    }
}
