//! Per-launch GPU cost model and whole-plan time prediction.
//!
//! One kernel launch multiplying `n x n` matrices costs
//!
//! ```text
//! t = overhead + transfer_bytes / pcie_bw + max(flops/eff_flops, bytes/mem_bw)
//! ```
//!
//! The three free parameters (`overhead_s`, effective PCIe bandwidth,
//! effective GFLOP/s) are calibrated against the paper's naive-GPU columns
//! (see [`crate::simulator::calibrate`]); the roofline `max` keeps small
//! matrices bandwidth/overhead bound and large ones compute bound, which
//! is exactly the transition visible between Table 2 (n=64,
//! overhead-dominated) and Table 5 (n=512, compute-dominated).

use crate::plan::{Plan, PlanCost, Step};
use crate::simulator::device::DeviceSpec;

/// Calibrated analytic model for one device.
#[derive(Clone, Debug)]
pub struct GpuTimingModel {
    /// The modeled device's spec sheet (Table 1).
    pub device: DeviceSpec,
    /// Fixed cost per kernel launch, seconds (driver + dispatch).
    pub launch_overhead_s: f64,
    /// Effective host↔device bandwidth, bytes/s.
    pub eff_pcie_bytes_per_s: f64,
    /// Effective sustained compute, FLOP/s.
    pub eff_flops: f64,
    /// Effective device-memory bandwidth, bytes/s.
    pub eff_mem_bytes_per_s: f64,
    /// Fixed cost per device-resident *invocation* (not per launch):
    /// context/queue setup + final sync. The paper's "Our Approach" column
    /// has a visible 10–20 ms floor (at n=64 it reports 10 ms for SIX
    /// launches, twice its own naive per-launch cost) — a constant the
    /// naive loop amortizes over N launches but a log(N)-launch run does
    /// not. Calibrated by [`crate::simulator::calibrate::fit_session_overhead`].
    pub session_overhead_s: f64,
    /// Per-size calibrated naive per-launch cost `(n, seconds)`, from the
    /// paper's own naive columns ([`crate::simulator::calibrate::fit_per_size`]).
    /// The paper's per-launch costs are NOT monotone in the analytic
    /// features (n=64 at N=1024 costs 2.6 ms/launch vs n=512's 3.4 ms), so
    /// no 3-parameter physical model fits all sizes; where the paper
    /// published a size we use its own numbers, and the analytic model
    /// interpolates everywhere else.
    pub per_size_launch_s: Vec<(usize, f64)>,
}

/// Predicted timing breakdown for executing a plan.
#[derive(Clone, Copy, Debug)]
pub struct SimReport {
    /// Predicted wall-clock seconds, all components summed.
    pub total_s: f64,
    /// Launch-dispatch (and session) overhead seconds.
    pub overhead_s: f64,
    /// Host↔device transfer seconds.
    pub transfer_s: f64,
    /// Roofline kernel-compute seconds.
    pub kernel_s: f64,
    /// Kernel launches the plan performs.
    pub launches: usize,
    /// Matrix multiplies across those launches.
    pub multiplies: usize,
}

impl GpuTimingModel {
    /// A reasonable uncalibrated model straight from the spec sheet:
    /// 35% of peak flops, 60% of peak PCIe/memory bandwidth, 2012-era
    /// OpenCL launch+sync overhead.
    pub fn from_spec(device: DeviceSpec) -> GpuTimingModel {
        GpuTimingModel {
            launch_overhead_s: 2.0e-3,
            eff_pcie_bytes_per_s: device.pcie_gbs * 1e9 * 0.6,
            eff_flops: device.peak_gflops * 1e9 * 0.35,
            eff_mem_bytes_per_s: device.bandwidth_gbs * 1e9 * 0.6,
            session_overhead_s: 0.0,
            per_size_launch_s: Vec::new(),
            device,
        }
    }

    /// Calibrated whole-launch cost for size `n`, if the paper reported it.
    pub fn calibrated_per_launch(&self, n: usize) -> Option<f64> {
        self.per_size_launch_s
            .iter()
            .find(|&&(size, _)| size == n)
            .map(|&(_, s)| s)
    }

    /// Effective dispatch overhead for one launch at size `n`: the
    /// calibrated whole-launch cost minus the analytic transfer+compute
    /// components (so a calibrated round-trip launch totals exactly the
    /// paper's own per-launch cost), else the analytic constant.
    pub fn eff_launch_overhead(&self, n: usize) -> f64 {
        match self.calibrated_per_launch(n) {
            Some(r) => (r - self.transfer_time(n, 3) - self.kernel_time(n, 1)).max(1e-5),
            None => self.launch_overhead_s,
        }
    }

    /// Time for the compute portion of one `n x n` matmul launch.
    pub fn kernel_time(&self, n: usize, multiplies: usize) -> f64 {
        let flops = 2.0 * (n as f64).powi(3) * multiplies as f64;
        // each multiply streams 3 matrices through device memory at least once
        let bytes = 3.0 * (n * n * 4) as f64 * multiplies as f64;
        (flops / self.eff_flops).max(bytes / self.eff_mem_bytes_per_s)
    }

    /// Time to move `count` matrices across the host↔device link.
    pub fn transfer_time(&self, n: usize, count: usize) -> f64 {
        (n * n * 4) as f64 * count as f64 / self.eff_pcie_bytes_per_s
    }

    /// Predict a device-resident plan execution (upload once, download
    /// once, plus the per-invocation session overhead).
    pub fn simulate_device_resident(&self, plan: &Plan, n: usize) -> SimReport {
        let cost = PlanCost::device_resident(plan, n);
        let mut r = self.report(plan, n, cost.h2d_transfers + cost.d2h_transfers);
        r.overhead_s += self.session_overhead_s;
        r.total_s += self.session_overhead_s;
        r
    }

    /// Predict a per-launch-roundtrip execution (naive §4.2 discipline).
    pub fn simulate_roundtrip(&self, plan: &Plan, n: usize) -> SimReport {
        let cost = PlanCost::per_launch_roundtrip(plan, n);
        self.report(plan, n, cost.h2d_transfers + cost.d2h_transfers)
    }

    fn report(&self, plan: &Plan, n: usize, transfers: usize) -> SimReport {
        let launches = plan.launches();
        let mut kernel_s = 0.0;
        for step in &plan.steps {
            if let Step::Copy { .. } = step {
                continue;
            }
            kernel_s += self.kernel_time(n, step.multiplies());
        }
        let overhead_s = self.eff_launch_overhead(n) * launches as f64;
        let transfer_s = self.transfer_time(n, transfers);
        SimReport {
            total_s: overhead_s + transfer_s + kernel_s,
            overhead_s,
            transfer_s,
            kernel_s,
            launches,
            multiplies: plan.multiplies(),
        }
    }

    /// Sequential-CPU prediction: `multiplies` naive triple-loop matmuls on
    /// one core of `cpu`.
    pub fn simulate_cpu(cpu: &DeviceSpec, n: usize, multiplies: usize) -> f64 {
        2.0 * (n as f64).powi(3) * multiplies as f64 / (cpu.peak_gflops * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Plan;

    fn model() -> GpuTimingModel {
        GpuTimingModel::from_spec(DeviceSpec::tesla_c2050())
    }

    #[test]
    fn ours_beats_naive_for_all_table_cells() {
        let m = model();
        for n in [64usize, 128, 256, 512] {
            for power in [64u64, 128, 256, 512, 1024] {
                let naive = m.simulate_roundtrip(&Plan::naive(power), n);
                let ours = m.simulate_device_resident(&Plan::binary(power, false), n);
                assert!(
                    ours.total_s < naive.total_s,
                    "n={n} N={power}: ours {} vs naive {}",
                    ours.total_s,
                    naive.total_s
                );
            }
        }
    }

    #[test]
    fn speedup_grows_with_power_at_fixed_size() {
        // the paper's key observation (Figs 5/7/9/11): ours-vs-naive gap
        // widens as the power grows
        let m = model();
        let n = 64;
        let mut last = 0.0;
        for power in [64u64, 128, 256, 512, 1024] {
            let naive = m.simulate_roundtrip(&Plan::naive(power), n).total_s;
            let ours = m.simulate_device_resident(&Plan::binary(power, false), n).total_s;
            let speedup = naive / ours;
            assert!(speedup > last, "power={power}: {speedup} <= {last}");
            last = speedup;
        }
    }

    #[test]
    fn small_matrices_overhead_bound_large_compute_bound() {
        let m = model();
        let small = m.simulate_roundtrip(&Plan::naive(256), 64);
        assert!(small.overhead_s > small.kernel_s, "n=64 should be overhead-bound");
        let large = m.simulate_roundtrip(&Plan::naive(256), 512);
        assert!(large.kernel_s > large.overhead_s * 0.1, "n=512 kernel time should matter");
    }

    #[test]
    fn kernel_time_is_roofline() {
        let m = model();
        // tiny matmul: bandwidth bound => time == bytes / mem_bw
        let t = m.kernel_time(8, 1);
        let bytes = 3.0 * (8.0 * 8.0 * 4.0);
        assert!((t - bytes / m.eff_mem_bytes_per_s).abs() / t < 1e-9);
        // big matmul: compute bound
        let t = m.kernel_time(2048, 1);
        let flops = 2.0 * 2048f64.powi(3);
        assert!((t - flops / m.eff_flops).abs() / t < 1e-9);
    }

    #[test]
    fn cpu_time_matches_paper_order_of_magnitude() {
        // Table 4: n=256, N=64 sequential CPU = 16 s
        let cpu = DeviceSpec::xeon_2012_single_core();
        let t = GpuTimingModel::simulate_cpu(&cpu, 256, 63);
        assert!(t > 0.4 && t < 40.0, "{t}");
    }
}
