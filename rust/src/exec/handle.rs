//! [`JobHandle`] — the future-like handle every [`crate::exec::Executor`]
//! returns from `submit`.
//!
//! Two shapes behind one API:
//!
//! * **Ready** — synchronous executors ([`crate::runtime::Engine`],
//!   [`crate::pool::PoolEngine`]) execute eagerly at submission; the
//!   handle already holds the outcome and `wait` just hands it over.
//! * **Pending** — the serving coordinator returns before execution; the
//!   handle owns the job's reply channel plus a reference to the
//!   service's reply registry, so `cancel`/deadline expiry/`Drop` can
//!   deregister the job instead of leaking its reply slot.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::request::ExpmResponse;
use crate::error::{MatexpError, Result};
use crate::trace::TraceId;

/// What a worker sends back for one job: the response, or the TYPED
/// error — the kind survives the thread hop, so a `Deadline` rejection
/// stays a `Deadline` at the handle (and keeps its kind on the wire).
pub type JobReply = std::result::Result<ExpmResponse, MatexpError>;

/// The sending half a worker uses to complete a job. Unbounded on
/// purpose: a worker must never block on a slow consumer.
pub type ReplySender = Sender<(u64, JobReply)>;

/// The coordinator's reply registry: job id → where to send the outcome.
/// Entries are removed by the worker on completion, and by the handle on
/// cancel / deadline expiry / drop — whichever comes first.
pub(crate) type ReplyRegistry = Arc<Mutex<HashMap<u64, ReplySender>>>;

enum State {
    /// Outcome already computed (synchronous executors). `None` once taken.
    Ready(Option<Result<ExpmResponse>>),
    /// In flight on a service.
    Pending { rx: Receiver<(u64, JobReply)>, replies: ReplyRegistry, done: bool },
    /// Cancelled by the caller.
    Cancelled,
}

/// Handle to one submitted job: `wait`, `try_result`, `cancel`, with
/// deadline expiry enforced at the waiting edge.
pub struct JobHandle {
    id: u64,
    trace: TraceId,
    deadline: Option<Instant>,
    state: State,
}

impl JobHandle {
    /// Handle over an already-computed outcome (synchronous executors).
    /// `deadline` is carried for the accessor's sake — the outcome is
    /// already decided, so it no longer gates anything.
    pub(crate) fn ready(
        id: u64,
        trace: TraceId,
        deadline: Option<Instant>,
        outcome: Result<ExpmResponse>,
    ) -> JobHandle {
        JobHandle { id, trace, deadline, state: State::Ready(Some(outcome)) }
    }

    /// Handle over an in-flight service job.
    pub(crate) fn pending(
        id: u64,
        trace: TraceId,
        deadline: Option<Instant>,
        rx: Receiver<(u64, JobReply)>,
        replies: ReplyRegistry,
    ) -> JobHandle {
        JobHandle { id, trace, deadline, state: State::Pending { rx, replies, done: false } }
    }

    /// The id the executor assigned this job.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The trace id correlating this job's [`crate::trace::Span`]s —
    /// what `matexp trace` dumps filter on.
    pub fn trace(&self) -> TraceId {
        self.trace
    }

    /// Absolute deadline, if the submission carried one.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Block until the job completes, its deadline expires, or the
    /// service goes away. Consumes the result: a second `wait` errors.
    pub fn wait(&mut self) -> Result<ExpmResponse> {
        let id = self.id;
        let deadline = self.deadline;
        match &mut self.state {
            State::Ready(slot) => slot
                .take()
                .ok_or_else(|| MatexpError::Service(format!("job {id}: result already taken"))),
            State::Cancelled => Err(MatexpError::Service(format!("job {id} was cancelled"))),
            State::Pending { rx, replies, done } => {
                if *done {
                    return Err(MatexpError::Service(format!("job {id}: result already taken")));
                }
                let received = match deadline {
                    None => rx.recv().map_err(|_| {
                        MatexpError::Service(format!("job {id}: service shut down in flight"))
                    }),
                    Some(d) => {
                        let remaining = d.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(remaining) {
                            Ok(reply) => Ok(reply),
                            Err(RecvTimeoutError::Timeout) => {
                                deregister(replies, id);
                                Err(MatexpError::Deadline(format!(
                                    "job {id} missed its deadline"
                                )))
                            }
                            Err(RecvTimeoutError::Disconnected) => {
                                deregister(replies, id);
                                Err(MatexpError::Service(format!(
                                    "job {id}: service shut down in flight"
                                )))
                            }
                        }
                    }
                };
                *done = true;
                received.and_then(|(_, reply)| reply)
            }
        }
    }

    /// Non-blocking poll. `None` means still in flight (or the result was
    /// already taken / the job was cancelled).
    pub fn try_result(&mut self) -> Option<Result<ExpmResponse>> {
        let id = self.id;
        let deadline = self.deadline;
        match &mut self.state {
            State::Ready(slot) => slot.take(),
            State::Cancelled => None,
            State::Pending { rx, replies, done } => {
                if *done {
                    return None;
                }
                match rx.try_recv() {
                    Ok((_, reply)) => {
                        *done = true;
                        Some(reply)
                    }
                    Err(TryRecvError::Empty) => {
                        if deadline.is_some_and(|d| Instant::now() > d) {
                            *done = true;
                            deregister(replies, id);
                            return Some(Err(MatexpError::Deadline(format!(
                                "job {id} missed its deadline"
                            ))));
                        }
                        None
                    }
                    Err(TryRecvError::Disconnected) => {
                        *done = true;
                        deregister(replies, id);
                        Some(Err(MatexpError::Service(format!(
                            "job {id}: service shut down in flight"
                        ))))
                    }
                }
            }
        }
    }

    /// Withdraw the job. Returns `true` if it was still pending
    /// server-side (its reply slot was deregistered before a worker
    /// completed it); `false` if it had already finished, was already
    /// cancelled, or ran synchronously.
    pub fn cancel(&mut self) -> bool {
        let withdrew = match &mut self.state {
            State::Pending { replies, done, .. } if !*done => {
                deregister(replies, self.id)
            }
            _ => return false,
        };
        self.state = State::Cancelled;
        withdrew
    }
}

/// Remove the job's reply slot; `true` if it was still registered.
fn deregister(replies: &ReplyRegistry, id: u64) -> bool {
    match replies.lock() {
        Ok(mut map) => map.remove(&id).is_some(),
        Err(_) => false,
    }
}

impl Drop for JobHandle {
    /// An abandoned handle deregisters its reply slot — otherwise a job
    /// whose caller lost interest would leak a registry entry forever if
    /// the worker side also dropped it.
    fn drop(&mut self) {
        if let State::Pending { replies, done, .. } = &mut self.state {
            if !*done {
                deregister(replies, self.id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;
    use crate::linalg::matrix::Matrix;
    use crate::runtime::ExecStats;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn resp(id: u64) -> ExpmResponse {
        ExpmResponse {
            id,
            result: Matrix::identity(2),
            stats: ExecStats::default(),
            method: Method::Ours,
            plan_kind: None,
        }
    }

    fn registry_with(id: u64, tx: ReplySender) -> ReplyRegistry {
        let registry: ReplyRegistry = Arc::new(Mutex::new(HashMap::new()));
        registry.lock().unwrap().insert(id, tx);
        registry
    }

    #[test]
    fn ready_handle_yields_once() {
        let mut h = JobHandle::ready(1, TraceId::from_raw(11), None, Ok(resp(1)));
        assert_eq!(h.id(), 1);
        assert_eq!(h.trace(), TraceId::from_raw(11));
        assert!(h.wait().is_ok());
        assert!(h.wait().is_err(), "second wait must not fabricate a result");
        assert!(!h.cancel(), "a completed job cannot be withdrawn");
    }

    #[test]
    fn pending_handle_delivers_worker_reply() {
        let (tx, rx) = channel();
        let registry = registry_with(7, tx.clone());
        let mut h = JobHandle::pending(7, TraceId::NONE, None, rx, Arc::clone(&registry));
        assert!(h.try_result().is_none(), "nothing sent yet");
        tx.send((7, Ok(resp(7)))).unwrap();
        let got = h.wait().unwrap();
        assert_eq!(got.id, 7);
    }

    #[test]
    fn deadline_expiry_is_typed_and_deregisters() {
        let (tx, rx) = channel();
        let registry = registry_with(3, tx);
        let deadline = Some(Instant::now() + Duration::from_millis(5));
        let mut h = JobHandle::pending(3, TraceId::NONE, deadline, rx, Arc::clone(&registry));
        match h.wait() {
            Err(MatexpError::Deadline(_)) => {}
            other => panic!("want deadline error, got {other:?}"),
        }
        assert!(registry.lock().unwrap().is_empty(), "expiry must deregister");
    }

    #[test]
    fn cancel_deregisters_and_poisons_wait() {
        let (tx, rx) = channel();
        let registry = registry_with(9, tx);
        let mut h = JobHandle::pending(9, TraceId::NONE, None, rx, Arc::clone(&registry));
        assert!(h.cancel());
        assert!(registry.lock().unwrap().is_empty());
        assert!(!h.cancel(), "double cancel is a no-op");
        assert!(matches!(h.wait(), Err(MatexpError::Service(_))));
    }

    #[test]
    fn drop_deregisters_abandoned_jobs() {
        let (tx, rx) = channel();
        let registry = registry_with(4, tx);
        drop(JobHandle::pending(4, TraceId::NONE, None, rx, Arc::clone(&registry)));
        assert!(registry.lock().unwrap().is_empty());
    }
}
