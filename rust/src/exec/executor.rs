//! The [`Executor`] trait — the one submission surface — and its
//! implementations for every execution layer:
//!
//! * [`Engine<B>`](crate::runtime::Engine) — synchronous: the submission
//!   executes eagerly and the returned handle is already complete.
//! * [`PoolEngine`] — synchronous at the surface; the pool parallelizes
//!   internally (tile shards / per-device queues).
//! * [`WorkerEngine`] — whatever a coordinator worker drives (single
//!   backend or shared pool), so the CLI routes through the same surface.
//! * [`ServiceHandle`] — genuinely asynchronous: `submit` enqueues and
//!   returns a pending handle; `wait`/`try_result`/`cancel`/deadlines
//!   operate on the in-flight job.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmResponse, Method};
use crate::coordinator::scheduler;
use crate::coordinator::service::ServiceHandle;
use crate::coordinator::worker::{self, WorkerEngine};
use crate::error::{MatexpError, Result};
use crate::exec::handle::JobHandle;
use crate::exec::submission::Submission;
use crate::pool::PoolEngine;
use crate::runtime::{Backend, Engine};

/// What an executor can serve — the machine-readable version of "which
/// submissions will this surface accept".
#[derive(Clone, Debug)]
pub struct Capabilities {
    /// Human-readable execution substrate description.
    pub platform: String,
    /// Methods this executor can run.
    pub methods: Vec<Method>,
    /// Servable matrix sizes; empty means size-unrestricted.
    pub sizes: Vec<usize>,
    /// Largest admissible exponent.
    pub max_power: u64,
    /// `true` when `submit` returns before the job executes (the serving
    /// coordinator); `false` for eager executors.
    pub async_submit: bool,
}

impl Capabilities {
    /// Capabilities of an eager (synchronous) executor serving every
    /// method at any size — the one place the shared policy lives, so
    /// the executors cannot drift apart.
    fn sync(platform: String) -> Capabilities {
        Capabilities {
            platform,
            methods: Method::all().to_vec(),
            sizes: Vec::new(),
            max_power: scheduler::MAX_POWER,
            async_submit: false,
        }
    }
}

/// One execution surface over engine, pool and service: submit a typed
/// [`Submission`], get a [`JobHandle`] back.
pub trait Executor {
    /// Submit one job. Synchronous executors run it before returning (the
    /// handle is complete); the service enqueues and returns immediately.
    fn submit(&mut self, submission: Submission) -> Result<JobHandle>;

    /// What this executor can serve.
    fn capabilities(&self) -> Capabilities;

    /// Convenience: `submit` + `wait`.
    fn run(&mut self, submission: Submission) -> Result<ExpmResponse> {
        self.submit(submission)?.wait()
    }
}

/// Config for bare-engine submissions: the crate defaults, resolved
/// once — EXCEPT the admission size cap, which exists to protect shared
/// serving capacity and has no business limiting a caller's own engine
/// (the deprecated `expm_*` entry points never capped size either).
fn bare_engine_cfg() -> &'static MatexpConfig {
    static CFG: OnceLock<MatexpConfig> = OnceLock::new();
    CFG.get_or_init(|| {
        let mut cfg = MatexpConfig::default();
        cfg.max_n = usize::MAX;
        cfg
    })
}

/// Ids for handles minted by synchronous executors (distinct per process,
/// so logs from interleaved engines stay readable).
fn next_sync_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Fail fast when a job's deadline has already passed.
pub(crate) fn check_deadline(deadline: Option<Instant>) -> Result<()> {
    if deadline.is_some_and(|d| Instant::now() > d) {
        return Err(MatexpError::Deadline("deadline expired before execution".into()));
    }
    Ok(())
}

/// Post-execution contract checks shared by every executor: a job that
/// finished after its deadline expires anyway, and a non-finite result
/// violates any requested tolerance.
pub(crate) fn enforce(
    deadline: Option<Instant>,
    tolerance: Option<f32>,
    resp: ExpmResponse,
) -> Result<ExpmResponse> {
    if deadline.is_some_and(|d| Instant::now() > d) {
        return Err(MatexpError::Deadline(format!(
            "request {} completed after its deadline",
            resp.id
        )));
    }
    if tolerance.is_some() && !resp.result.is_finite() {
        return Err(MatexpError::Service(format!(
            "request {}: result violates the requested tolerance: non-finite \
             entries (did the power overflow f32?)",
            resp.id
        )));
    }
    Ok(resp)
}

/// Every executor admits with [`scheduler::admit`] before executing, so
/// the one surface rejects the same submissions everywhere (power 0 /
/// over-limit, empty or non-finite matrices, unmeetable tolerances) with
/// the same typed errors the service returns.
///
/// A bare `Engine<B>` has no caller configuration, so its strategy
/// dispatch and admission limits resolve against the crate-default
/// [`MatexpConfig`]. Config-sensitive submissions should either pin an
/// explicit [`Submission::plan`] (the experiments do) or go through a
/// config-built [`WorkerEngine`] / the service, which dispatch with the
/// caller's config.
impl<B: Backend> Executor for Engine<B> {
    fn submit(&mut self, submission: Submission) -> Result<JobHandle> {
        let cfg = bare_engine_cfg();
        let req = submission.into_request(next_sync_id());
        scheduler::admit(&req, &[], cfg)?;
        let outcome = worker::execute_request(self, cfg, &req);
        Ok(JobHandle::ready(req.id, req.trace, req.deadline, outcome))
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::sync(self.platform())
    }
}

impl Executor for PoolEngine {
    fn submit(&mut self, submission: Submission) -> Result<JobHandle> {
        let req = submission.into_request(next_sync_id());
        let (id, trace, deadline) = (req.id, req.trace, req.deadline);
        scheduler::admit(&req, &[], self.pool().config())?;
        let outcome = self.execute_request(req);
        Ok(JobHandle::ready(id, trace, deadline, outcome))
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::sync(self.platform())
    }
}

impl Executor for WorkerEngine {
    fn submit(&mut self, submission: Submission) -> Result<JobHandle> {
        let req = submission.into_request(next_sync_id());
        let (id, trace, deadline) = (req.id, req.trace, req.deadline);
        // admit and dispatch with the config the worker was built from
        // (the CLI's loaded config), not crate defaults
        scheduler::admit(&req, &[], self.config())?;
        let outcome = worker::execute(self, req);
        Ok(JobHandle::ready(id, trace, deadline, outcome))
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::sync(self.platform())
    }
}

impl Executor for ServiceHandle {
    fn submit(&mut self, submission: Submission) -> Result<JobHandle> {
        ServiceHandle::submit_job(self, submission)
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            sizes: self.sizes().to_vec(),
            async_submit: true,
            ..Capabilities::sync(self.platform())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, CpuAlgo, Matrix};
    use crate::plan::Plan;

    #[test]
    fn engine_submit_returns_completed_handle() {
        let mut engine = Engine::cpu(CpuAlgo::Ikj);
        let a = Matrix::random_spectral(8, 0.9, 2);
        let want = linalg::expm::expm(&a, 13, CpuAlgo::Ikj).unwrap();
        let mut handle = engine.submit(Submission::expm(a, 13)).unwrap();
        let resp = handle.try_result().expect("eager executor completes at submit").unwrap();
        assert!(resp.result.approx_eq(&want, 1e-4, 1e-4));
        let caps = engine.capabilities();
        assert!(!caps.async_submit);
        assert!(caps.sizes.is_empty());
        assert!(caps.methods.contains(&Method::PlanRoundtrip));
    }

    #[test]
    fn plan_override_drives_the_exact_schedule() {
        let mut engine = Engine::cpu(CpuAlgo::Ikj);
        let a = Matrix::random_spectral(8, 0.9, 4);
        let plan = Plan::binary(100, false);
        let launches = plan.launches();
        let resp = engine.run(Submission::expm(a, 100).plan(plan)).unwrap();
        assert_eq!(resp.stats.launches, launches);
        assert_eq!(resp.plan_kind, Some(crate::plan::PlanKind::Binary));
    }

    #[test]
    fn expired_deadline_fails_typed_even_on_sync_executors() {
        let mut engine = Engine::cpu(CpuAlgo::Ikj);
        let a = Matrix::identity(4);
        let err = engine
            .run(Submission::expm(a, 2).deadline(std::time::Duration::ZERO))
            .unwrap_err();
        assert!(matches!(err, MatexpError::Deadline(_)), "{err:?}");
    }

    /// Regression: sync executors used to skip admission entirely —
    /// power 0 panicked in plan construction instead of returning the
    /// service's typed rejection.
    #[test]
    fn sync_executors_admit_like_the_service() {
        let mut engine = Engine::cpu(CpuAlgo::Ikj);
        let err = engine.run(Submission::expm(Matrix::identity(4), 0)).unwrap_err();
        assert!(err.to_string().contains("power"), "{err}");
        let mut bad = Matrix::identity(4);
        bad.set(0, 0, f32::NAN);
        assert!(engine.run(Submission::expm(bad, 4)).is_err(), "non-finite input admitted");
        let err = engine
            .run(Submission::expm(Matrix::identity(4), 4).tolerance(f32::NAN))
            .unwrap_err();
        assert!(matches!(err, MatexpError::Admission(_)), "{err:?}");

        let mut pool_cfg = MatexpConfig::default();
        pool_cfg.backend = crate::runtime::BackendKind::Pool;
        pool_cfg.pool.devices =
            vec![crate::pool::PoolDeviceKind::Cpu, crate::pool::PoolDeviceKind::Cpu];
        let mut pool = PoolEngine::from_config(&pool_cfg).unwrap();
        assert!(pool.run(Submission::expm(Matrix::identity(4), 0)).is_err());
    }

    /// Regression: the CLI's WorkerEngine used to dispatch against the
    /// crate-default config, silently ignoring `use_square_chains=false`.
    #[test]
    fn worker_engine_dispatches_with_its_own_config() {
        let mut cfg = MatexpConfig::default();
        cfg.use_square_chains = false;
        let mut engine = worker::build_worker_engine(&cfg, None).unwrap();
        let resp = engine.run(Submission::expm(Matrix::identity(8), 100)).unwrap();
        assert_eq!(resp.plan_kind, Some(crate::plan::PlanKind::Binary));

        cfg.use_square_chains = true;
        let mut engine = worker::build_worker_engine(&cfg, None).unwrap();
        let resp = engine.run(Submission::expm(Matrix::identity(8), 100)).unwrap();
        assert_eq!(resp.plan_kind, Some(crate::plan::PlanKind::Chained));
    }

    #[test]
    fn tolerance_rejects_overflowed_results() {
        let mut engine = Engine::cpu(CpuAlgo::Ikj);
        // spectral radius 3: A^64 overflows f32 to +inf
        let mut a = Matrix::identity(4);
        for i in 0..4 {
            a.set(i, i, 3.0);
        }
        let err = engine.run(Submission::expm(a.clone(), 512).tolerance(1e-4)).unwrap_err();
        assert!(matches!(err, MatexpError::Service(_)), "{err:?}");
        // without a tolerance the (non-finite) result is handed back as-is
        let resp = engine.run(Submission::expm(a, 512)).unwrap();
        assert!(!resp.result.is_finite());
    }
}
