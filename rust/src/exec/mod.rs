//! # The execution surface
//!
//! One typed submission API in front of every executor in the crate —
//! the engine, the device pool, and the serving coordinator all accept
//! the same [`Submission`] and answer with the same [`JobHandle`]:
//!
//! ```text
//!   Submission::expm(A, N).method(..).plan(..).deadline(..).priority(..)
//!        │                       Executor::submit
//!        ├────────▶ Engine<B>      (eager: handle is already complete)
//!        ├────────▶ PoolEngine     (eager surface, parallel inside)
//!        └────────▶ ServiceHandle  (async: wait / try_result / cancel)
//! ```
//!
//! What used to be seven ad-hoc `expm_*` engine entry points, a
//! divergent pool subset and a blocking-only `ServiceHandle::submit` is
//! now one vocabulary: a [`Submission`] names *what* to compute (matrix,
//! power, [`Method`](crate::coordinator::request::Method), optional
//! explicit [`Plan`](crate::plan::Plan)) and *how it must be served*
//! (deadline, [`Priority`], tolerance, [`CacheControl`]); the
//! [`Executor`] decides how to run it. The legacy entry points were
//! deprecated in 0.3.0 and **removed** in 0.4.0 (a source-grep test
//! keeps them from creeping back); the old→new migration table lives in
//! the crate docs ([`crate`]).
//!
//! ```
//! use matexp::prelude::*;
//!
//! let a = Matrix::random_spectral(32, 0.99, 42);
//! let want = Engine::cpu(CpuAlgo::Ikj)
//!     .run(Submission::expm(a.clone(), 512))
//!     .unwrap();
//!
//! // the identical submission through the multi-device pool
//! let mut cfg = MatexpConfig::default();
//! cfg.backend = BackendKind::Pool;
//! cfg.pool.devices = vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu];
//! let mut pool = PoolEngine::from_config(&cfg).unwrap();
//! let got = pool.run(Submission::expm(a, 512)).unwrap();
//! assert!(got.result.approx_eq(&want.result, 1e-3, 1e-3));
//! assert!(!pool.capabilities().async_submit);
//! ```

pub mod executor;
pub mod handle;
pub mod submission;

pub use executor::{Capabilities, Executor};
pub use handle::{JobHandle, JobReply, ReplySender};
pub use submission::{Priority, Submission};

pub use crate::cache::CacheControl;

pub(crate) use executor::{check_deadline, enforce};
pub(crate) use handle::ReplyRegistry;
