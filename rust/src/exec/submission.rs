//! [`Submission`] — the one typed description of "compute `A^N` for me".
//!
//! A submission subsumes what used to be spread across `ExpmRequest`
//! construction, `Method` selection and ad-hoc `expm_*` entry points:
//! the operand, the exponent, the execution method, an optional explicit
//! launch [`Plan`], and the serving qualifiers (deadline, priority,
//! tolerance) the coordinator acts on.

use std::time::{Duration, Instant};

use crate::cache::CacheControl;
use crate::coordinator::request::{ExpmRequest, Method};
use crate::error::MatexpError;
use crate::linalg::matrix::Matrix;
use crate::plan::Plan;
use crate::trace::TraceId;

/// Scheduling priority of a submission.
///
/// `High` submissions skip batch coalescing: the batcher ships the batch
/// they land in immediately instead of waiting for batch-mates. `Low`
/// submissions coalesce harder: an all-low batch may wait several times
/// the configured batch deadline, yielding the workers to fresher
/// traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-insensitive work (bulk experiments, warmup): waits longer
    /// for batch-mates than the configured batch deadline.
    Low,
    /// The default: size-or-deadline batching.
    #[default]
    Normal,
    /// Ship immediately; don't wait for batch-mates.
    High,
}

impl Priority {
    /// Canonical lowercase name (CLI/config vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Every priority, for exhaustive parsing/tests.
    pub fn all() -> [Priority; 3] {
        [Priority::Low, Priority::Normal, Priority::High]
    }
}

impl std::str::FromStr for Priority {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Priority::all()
            .into_iter()
            .find(|p| p.as_str() == s.to_ascii_lowercase())
            .ok_or_else(|| {
                MatexpError::Config(format!("unknown priority {s:?} (low|normal|high)"))
            })
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One typed unit of work for any [`crate::exec::Executor`].
///
/// Built with [`Submission::expm`] plus chainable qualifiers:
///
/// ```
/// use matexp::prelude::*;
///
/// let a = Matrix::random_spectral(16, 0.95, 7);
/// let resp = Engine::cpu(CpuAlgo::Ikj)
///     .run(
///         Submission::expm(a, 100)
///             .method(Method::OursPacked)
///             .deadline(std::time::Duration::from_secs(30))
///             .priority(Priority::High)
///             .tolerance(1e-4),
///     )
///     .unwrap();
/// // the packed discipline touches the host exactly twice
/// assert_eq!((resp.stats.h2d_transfers, resp.stats.d2h_transfers), (1, 1));
/// ```
#[derive(Clone, Debug)]
pub struct Submission {
    /// The operand matrix.
    pub matrix: Matrix,
    /// The exponent `N` in `A^N`.
    pub power: u64,
    /// Execution method (defaults to [`Method::Ours`]).
    pub method: Method,
    /// Explicit launch plan, overriding the scheduler's choice. Local
    /// submissions only — the wire protocol does not carry plans.
    pub plan: Option<Plan>,
    /// Relative completion deadline. Resolved to an absolute instant at
    /// submission time; expired jobs fail with
    /// [`crate::error::MatexpError::Deadline`].
    pub deadline: Option<Duration>,
    /// Scheduling priority (see [`Priority`]).
    pub priority: Priority,
    /// Requested accuracy bound. Tight tolerances (< 1e-6) pin the
    /// conservative binary plan instead of chained launches, and a
    /// non-finite result violates any tolerance (typed error instead of
    /// silently returning infinities).
    pub tolerance: Option<f32>,
    /// How this submission interacts with the [`crate::cache`] tiers:
    /// `Use` (default) reads and populates, `Bypass` touches nothing,
    /// `Refresh` recomputes and overwrites. Local submissions only — the
    /// wire protocol always uses the server's default policy.
    pub cache: CacheControl,
    /// The trace id correlating every [`crate::trace::Span`] this
    /// submission produces, minted at construction.
    pub trace: TraceId,
}

impl Submission {
    /// A submission computing `matrix^power` with [`Method::Ours`].
    pub fn expm(matrix: Matrix, power: u64) -> Submission {
        Submission {
            matrix,
            power,
            method: Method::Ours,
            plan: None,
            deadline: None,
            priority: Priority::default(),
            tolerance: None,
            cache: CacheControl::default(),
            trace: TraceId::mint(),
        }
    }

    /// Matrix side length.
    pub fn n(&self) -> usize {
        self.matrix.n()
    }

    /// Select the execution method.
    pub fn method(mut self, method: Method) -> Submission {
        self.method = method;
        self
    }

    /// Pin an explicit launch plan (experiments and ablations; overrides
    /// the scheduler's method→plan mapping).
    pub fn plan(mut self, plan: Plan) -> Submission {
        self.plan = Some(plan);
        self
    }

    /// Fail the job if it has not completed within `deadline`.
    pub fn deadline(mut self, deadline: Duration) -> Submission {
        self.deadline = Some(deadline);
        self
    }

    /// Set the scheduling priority (see [`Priority`]).
    pub fn priority(mut self, priority: Priority) -> Submission {
        self.priority = priority;
        self
    }

    /// Request an accuracy bound (see the field docs for semantics).
    pub fn tolerance(mut self, tolerance: f32) -> Submission {
        self.tolerance = Some(tolerance);
        self
    }

    /// Steer the caching tiers for this submission (see [`CacheControl`]).
    ///
    /// ```
    /// use matexp::prelude::*;
    ///
    /// let a = Matrix::random_spectral(8, 0.9, 1);
    /// // Bypass: rebuild the plan, recompute the result — and store
    /// // nothing. The execution really runs: launches are reported.
    /// let resp = Engine::cpu(CpuAlgo::Ikj)
    ///     .run(Submission::expm(a, 16).cache(CacheControl::Bypass))
    ///     .unwrap();
    /// assert!(resp.stats.launches > 0);
    /// ```
    pub fn cache(mut self, cache: CacheControl) -> Submission {
        self.cache = cache;
        self
    }

    /// Lower into the coordinator's request type, resolving the relative
    /// deadline against the clock now.
    pub(crate) fn into_request(self, id: u64) -> ExpmRequest {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.into_request_at(id, deadline)
    }

    /// [`Self::into_request`] with a pre-resolved absolute deadline (so a
    /// caller that also hands the deadline to a [`crate::exec::JobHandle`]
    /// uses one consistent instant).
    pub(crate) fn into_request_at(self, id: u64, deadline: Option<Instant>) -> ExpmRequest {
        ExpmRequest {
            id,
            matrix: self.matrix,
            power: self.power,
            method: self.method,
            plan: self.plan,
            deadline,
            priority: self.priority,
            tolerance: self.tolerance,
            cache: self.cache,
            trace: self.trace,
            queued_at: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn builder_sets_every_field() {
        let sub = Submission::expm(Matrix::identity(8), 64)
            .method(Method::NaiveGpu)
            .plan(Plan::binary(64, false))
            .deadline(Duration::from_millis(250))
            .priority(Priority::High)
            .tolerance(1e-3);
        assert_eq!(sub.n(), 8);
        assert_eq!(sub.power, 64);
        assert_eq!(sub.method, Method::NaiveGpu);
        assert!(sub.plan.is_some());
        assert_eq!(sub.deadline, Some(Duration::from_millis(250)));
        assert_eq!(sub.priority, Priority::High);
        assert_eq!(sub.tolerance, Some(1e-3));

        let req = sub.into_request(9);
        assert_eq!(req.id, 9);
        assert_ne!(req.trace, TraceId::NONE, "lowering keeps the minted trace id");
        assert_eq!(req.method, Method::NaiveGpu);
        assert!(req.deadline.is_some());
        assert_eq!(req.priority, Priority::High);
    }

    #[test]
    fn defaults_are_ours_normal_no_deadline() {
        let sub = Submission::expm(Matrix::identity(4), 2);
        assert_eq!(sub.method, Method::Ours);
        assert_eq!(sub.priority, Priority::Normal);
        assert_eq!(sub.cache, CacheControl::Use);
        assert!(sub.deadline.is_none() && sub.plan.is_none() && sub.tolerance.is_none());
    }

    #[test]
    fn cache_control_lowers_into_the_request() {
        for ctl in CacheControl::all() {
            let req = Submission::expm(Matrix::identity(4), 2).cache(ctl).into_request(1);
            assert_eq!(req.cache, ctl);
        }
    }

    #[test]
    fn priority_parse_roundtrip() {
        for p in Priority::all() {
            assert_eq!(Priority::from_str(p.as_str()).unwrap(), p);
        }
        assert!(Priority::from_str("urgent").is_err());
        assert_eq!(Priority::from_str("HIGH").unwrap(), Priority::High);
    }
}
