//! [`FsSink`] — the durable [`Sink`]: one file per entry with a
//! checksummed header, atomic temp-file + rename commits, and a
//! rebuild-on-open index.
//!
//! On-disk entry layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic        b"MXST"
//!      4     2  version      1
//!      6     1  kind         ArtifactKind tag
//!      7     1  reserved     0
//!      8     8  key hi       high half of the content digest
//!     16     8  key lo       low half of the content digest
//!     24     8  payload_len  bytes of payload that follow the header
//!     32     8  checksum     xxh64-style sum of bytes [4..32] + payload
//!     40     …  payload      codec-specific artifact bytes
//! ```
//!
//! The checksum covers everything identifying after the magic — version,
//! kind, key, declared length — plus the payload, and the sum itself is
//! length-seeded, so truncation at *any* byte boundary, a bit flip
//! anywhere, or a cross-renamed file all fail verification. `get`
//! re-verifies on every read (bit rot after open is still caught) and
//! answers the typed [`MatexpError::Store`] for a damaged entry — never
//! wrong bits, and never affecting any other entry.
//!
//! Writes go to a `.tmp` file first and `rename(2)` into place, so a
//! crash mid-write leaves either the old committed entry or a stray
//! temp file — [`FsSink::open`] sweeps temp files and verifies every
//! committed entry, skipping (and removing) torn ones while the healthy
//! entries keep serving.

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{MatexpError, Result};
use crate::store::{checksum, ArtifactKind, Sink, StoreKey};

/// Entry-file magic: "matexp store".
pub const MAGIC: [u8; 4] = *b"MXST";
/// Current entry-format version.
pub const VERSION: u16 = 1;
/// Bytes of header before the payload.
pub const HEADER_LEN: usize = 40;
/// Extension of committed entry files.
pub const ENTRY_EXT: &str = "mxst";
/// Extension of not-yet-committed temp files (swept on open).
pub const TMP_EXT: &str = "tmp";

/// Serialize the header for (`key`, `payload`), checksum included.
fn encode_header(key: &StoreKey, payload: &[u8]) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(&MAGIC);
    h[4..6].copy_from_slice(&VERSION.to_le_bytes());
    h[6] = key.kind.tag();
    h[7] = 0;
    h[8..16].copy_from_slice(&key.hi.to_le_bytes());
    h[16..24].copy_from_slice(&key.lo.to_le_bytes());
    h[24..32].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    let sum = entry_checksum(&h, payload);
    h[32..40].copy_from_slice(&sum.to_le_bytes());
    h
}

/// The sum stored at header offset 32: bytes `[4..32]` of the header
/// (everything after the magic, before the sum) followed by the payload.
fn entry_checksum(header: &[u8; HEADER_LEN], payload: &[u8]) -> u64 {
    let mut buf = Vec::with_capacity(28 + payload.len());
    buf.extend_from_slice(&header[4..32]);
    buf.extend_from_slice(payload);
    checksum(&buf)
}

/// Parse and fully verify one entry file's bytes; the verified payload
/// on success, a typed [`MatexpError::Store`] naming what failed
/// otherwise.
fn verify_entry(bytes: &[u8]) -> Result<(StoreKey, Vec<u8>)> {
    let bad = |what: &str| MatexpError::Store(format!("corrupt store entry: {what}"));
    if bytes.len() < HEADER_LEN {
        return Err(bad(&format!("truncated header ({} of {HEADER_LEN} bytes)", bytes.len())));
    }
    let header: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().expect("length checked");
    if header[0..4] != MAGIC {
        return Err(bad("bad magic"));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().expect("sized"));
    if version != VERSION {
        return Err(bad(&format!("unsupported version {version}")));
    }
    let kind = ArtifactKind::from_tag(header[6])
        .ok_or_else(|| bad(&format!("unknown artifact kind {}", header[6])))?;
    let u64_at = |at: usize| u64::from_le_bytes(header[at..at + 8].try_into().expect("sized"));
    let payload_len = u64_at(24) as usize;
    if bytes.len() != HEADER_LEN + payload_len {
        return Err(bad(&format!(
            "length mismatch (file {} bytes, header declares {})",
            bytes.len(),
            HEADER_LEN + payload_len
        )));
    }
    let payload = &bytes[HEADER_LEN..];
    if entry_checksum(&header, payload) != u64_at(32) {
        return Err(bad("checksum mismatch"));
    }
    let key = StoreKey { kind, hi: u64_at(8), lo: u64_at(16) };
    Ok((key, payload.to_vec()))
}

/// Filesystem [`Sink`]: one verified file per entry under a root
/// directory. See the module docs for format and crash semantics.
pub struct FsSink {
    root: PathBuf,
    /// Key → payload length, rebuilt by scanning-and-verifying on open.
    index: Mutex<HashMap<StoreKey, u64>>,
    /// Temp-file name uniqueness across threads.
    seq: AtomicU64,
}

impl FsSink {
    /// Open (creating if needed) a store directory: sweep leftover temp
    /// files, verify every committed entry, and index the healthy ones.
    /// Torn or corrupt entries are removed — they are exactly the state
    /// an interrupted write may leave, and keeping them would turn every
    /// future read into an error.
    pub fn open(root: impl AsRef<Path>) -> Result<FsSink> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root).map_err(|e| {
            MatexpError::Store(format!("cannot create store dir {}: {e}", root.display()))
        })?;
        let mut index = HashMap::new();
        let entries = fs::read_dir(&root).map_err(|e| {
            MatexpError::Store(format!("cannot read store dir {}: {e}", root.display()))
        })?;
        for dirent in entries {
            let Ok(dirent) = dirent else { continue };
            let path = dirent.path();
            let ext = path.extension().and_then(|e| e.to_str());
            if ext == Some(TMP_EXT) {
                let _ = fs::remove_file(&path); // interrupted write, never committed
                continue;
            }
            if ext != Some(ENTRY_EXT) {
                continue; // not ours
            }
            match fs::read(&path).map_err(|e| MatexpError::Store(e.to_string())).and_then(
                |bytes| verify_entry(&bytes),
            ) {
                Ok((key, payload)) => {
                    index.insert(key, payload.len() as u64);
                }
                Err(_) => {
                    let _ = fs::remove_file(&path); // torn entry: skip and clean up
                }
            }
        }
        Ok(FsSink { root, index: Mutex::new(index), seq: AtomicU64::new(0) })
    }

    /// The directory this sink stores under.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The committed file path for `key`.
    pub fn entry_path(&self, key: &StoreKey) -> PathBuf {
        self.root.join(format!("{}.{ENTRY_EXT}", key.hex()))
    }
}

impl Sink for FsSink {
    fn put(&self, key: StoreKey, payload: &[u8]) -> Result<()> {
        let tmp = self.root.join(format!(
            "{}-{}.{TMP_EXT}",
            key.hex(),
            self.seq.fetch_add(1, Ordering::Relaxed)
        ));
        let header = encode_header(&key, payload);
        let write = (|| -> std::io::Result<()> {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(&header)?;
            f.write_all(payload)?;
            f.sync_all()?; // the bytes must be durable before the rename commits them
            Ok(())
        })();
        if let Err(e) = write {
            let _ = fs::remove_file(&tmp);
            return Err(MatexpError::Store(format!(
                "cannot write store entry {}: {e}",
                tmp.display()
            )));
        }
        fs::rename(&tmp, self.entry_path(&key)).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            MatexpError::Store(format!("cannot commit store entry {}: {e}", key.hex()))
        })?;
        self.index.lock().expect("fs index poisoned").insert(key, payload.len() as u64);
        Ok(())
    }

    fn get(&self, key: &StoreKey) -> Result<Option<Vec<u8>>> {
        if !self.contains(key) {
            return Ok(None);
        }
        let path = self.entry_path(key);
        let bytes = match fs::read(&path) {
            Ok(bytes) => bytes,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // deleted behind our back: a miss, not corruption
                self.index.lock().expect("fs index poisoned").remove(key);
                return Ok(None);
            }
            Err(e) => {
                return Err(MatexpError::Store(format!(
                    "cannot read store entry {}: {e}",
                    path.display()
                )))
            }
        };
        let (stored_key, payload) = verify_entry(&bytes)?;
        if stored_key != *key {
            return Err(MatexpError::Store(format!(
                "store entry {} holds key {} (cross-renamed file?)",
                key.hex(),
                stored_key.hex()
            )));
        }
        Ok(Some(payload))
    }

    fn delete(&self, key: &StoreKey) -> Result<bool> {
        let existed = self.index.lock().expect("fs index poisoned").remove(key).is_some();
        match fs::remove_file(self.entry_path(key)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(existed),
            Err(e) => Err(MatexpError::Store(format!("cannot delete {}: {e}", key.hex()))),
        }
    }

    fn len(&self) -> usize {
        self.index.lock().expect("fs index poisoned").len()
    }

    fn keys(&self) -> Vec<StoreKey> {
        self.index.lock().expect("fs index poisoned").keys().copied().collect()
    }

    fn bytes(&self) -> u64 {
        self.index.lock().expect("fs index poisoned").values().sum()
    }

    fn contains(&self, key: &StoreKey) -> bool {
        self.index.lock().expect("fs index poisoned").contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn key(lo: u64) -> StoreKey {
        StoreKey { kind: ArtifactKind::Result, hi: 0xfeed, lo }
    }

    #[test]
    fn roundtrip_survives_reopen() {
        let dir = TempDir::new().expect("tempdir");
        let sink = FsSink::open(dir.path()).expect("open");
        sink.put(key(1), b"hello").unwrap();
        sink.put(key(2), &[0u8; 300]).unwrap();
        assert_eq!(sink.get(&key(1)).unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(sink.bytes(), 305);
        drop(sink);
        let reopened = FsSink::open(dir.path()).expect("reopen");
        assert_eq!(reopened.len(), 2, "index rebuilds from disk");
        assert_eq!(reopened.get(&key(1)).unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(reopened.get(&key(2)).unwrap().as_deref(), Some(&[0u8; 300][..]));
        assert_eq!(reopened.get(&key(3)).unwrap(), None, "absent is a miss, not an error");
    }

    #[test]
    fn bit_flip_is_a_typed_store_error_and_isolated() {
        let dir = TempDir::new().expect("tempdir");
        let sink = FsSink::open(dir.path()).expect("open");
        sink.put(key(1), b"precious bits").unwrap();
        sink.put(key(2), b"innocent bystander").unwrap();
        // flip one payload bit on disk
        let path = sink.entry_path(&key(1));
        let mut bytes = fs::read(&path).unwrap();
        let at = HEADER_LEN + 3;
        bytes[at] ^= 0x10;
        fs::write(&path, &bytes).unwrap();
        match sink.get(&key(1)) {
            Err(MatexpError::Store(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("corrupt entry must be a typed store error: {other:?}"),
        }
        // the other entry keeps serving
        assert_eq!(sink.get(&key(2)).unwrap().as_deref(), Some(&b"innocent bystander"[..]));
    }

    #[test]
    fn reopen_sweeps_temp_files_and_torn_entries() {
        let dir = TempDir::new().expect("tempdir");
        let sink = FsSink::open(dir.path()).expect("open");
        sink.put(key(1), b"committed").unwrap();
        sink.put(key(2), b"will be torn").unwrap();
        let torn_path = sink.entry_path(&key(2));
        drop(sink);
        // simulate a crash: a leftover temp file and a truncated entry
        fs::write(dir.path().join("deadbeef-0.tmp"), b"partial write").unwrap();
        let bytes = fs::read(&torn_path).unwrap();
        fs::write(&torn_path, &bytes[..bytes.len() - 4]).unwrap();
        let reopened = FsSink::open(dir.path()).expect("reopen");
        assert_eq!(reopened.len(), 1, "torn entry skipped by the rebuild");
        assert!(reopened.contains(&key(1)));
        assert!(!reopened.contains(&key(2)));
        assert_eq!(reopened.get(&key(1)).unwrap().as_deref(), Some(&b"committed"[..]));
        // both damaged files were cleaned off disk
        let leftover: Vec<_> = fs::read_dir(dir.path())
            .unwrap()
            .filter_map(|d| d.ok())
            .filter(|d| {
                let name = d.file_name();
                let name = name.to_string_lossy().into_owned();
                name.ends_with(".tmp") || name == torn_path.file_name().unwrap().to_string_lossy()
            })
            .collect();
        assert!(leftover.is_empty(), "sweep leaves no damaged files: {leftover:?}");
    }

    #[test]
    fn header_rejects_every_tamper_axis() {
        let payload = b"payload";
        let k = key(9);
        let header = encode_header(&k, payload);
        let mut file = header.to_vec();
        file.extend_from_slice(payload);
        assert_eq!(verify_entry(&file).unwrap().0, k, "clean entry verifies");
        // every single-byte truncation fails
        for cut in 0..file.len() {
            assert!(verify_entry(&file[..cut]).is_err(), "truncation at {cut} must fail");
        }
        // magic, version, kind, key, length, sum: each tamper is caught
        for at in [0, 4, 6, 8, 16, 24, 32, HEADER_LEN] {
            let mut bad = file.clone();
            bad[at] ^= 0xff;
            assert!(verify_entry(&bad).is_err(), "tamper at byte {at} must fail");
        }
    }
}
