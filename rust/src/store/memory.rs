//! [`MemorySink`] — the in-process [`Sink`]: a mutex-guarded map.
//!
//! Exists for tests, for fault-injection wrappers to delegate to, and as
//! the executable specification of the [`Sink`] contract (the durability
//! suites run every invariant against both sinks). It never corrupts, so
//! its `get` never answers the typed store error — corruption semantics
//! are exercised through [`crate::store::FsSink`] and the injectable
//! wrappers in the integration tests.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::Result;
use crate::store::{Sink, StoreKey};

/// In-memory [`Sink`]: payloads in a mutex-guarded map.
#[derive(Default)]
pub struct MemorySink {
    entries: Mutex<HashMap<StoreKey, Vec<u8>>>,
}

impl MemorySink {
    /// An empty sink.
    pub fn new() -> MemorySink {
        MemorySink::default()
    }
}

impl Sink for MemorySink {
    fn put(&self, key: StoreKey, payload: &[u8]) -> Result<()> {
        self.entries.lock().expect("memory sink poisoned").insert(key, payload.to_vec());
        Ok(())
    }

    fn get(&self, key: &StoreKey) -> Result<Option<Vec<u8>>> {
        Ok(self.entries.lock().expect("memory sink poisoned").get(key).cloned())
    }

    fn delete(&self, key: &StoreKey) -> Result<bool> {
        Ok(self.entries.lock().expect("memory sink poisoned").remove(key).is_some())
    }

    fn len(&self) -> usize {
        self.entries.lock().expect("memory sink poisoned").len()
    }

    fn keys(&self) -> Vec<StoreKey> {
        self.entries.lock().expect("memory sink poisoned").keys().copied().collect()
    }

    fn bytes(&self) -> u64 {
        self.entries
            .lock()
            .expect("memory sink poisoned")
            .values()
            .map(|p| p.len() as u64)
            .sum()
    }

    fn contains(&self, key: &StoreKey) -> bool {
        self.entries.lock().expect("memory sink poisoned").contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ArtifactKind;

    fn key(lo: u64) -> StoreKey {
        StoreKey { kind: ArtifactKind::Result, hi: 1, lo }
    }

    #[test]
    fn sink_contract_roundtrip() {
        let sink = MemorySink::new();
        assert!(sink.is_empty());
        assert_eq!(sink.get(&key(1)).unwrap(), None);
        sink.put(key(1), b"abc").unwrap();
        sink.put(key(2), b"defg").unwrap();
        assert_eq!(sink.get(&key(1)).unwrap().as_deref(), Some(&b"abc"[..]));
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.bytes(), 7);
        assert!(sink.contains(&key(2)));
        sink.put(key(1), b"replaced").unwrap();
        assert_eq!(sink.len(), 2, "replacement does not grow the sink");
        assert_eq!(sink.get(&key(1)).unwrap().as_deref(), Some(&b"replaced"[..]));
        assert!(sink.delete(&key(1)).unwrap());
        assert!(!sink.delete(&key(1)).unwrap());
        assert_eq!(sink.keys(), vec![key(2)]);
    }
}
