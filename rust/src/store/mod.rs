//! Persistent, tiered result/artifact store — warm state that survives
//! the process.
//!
//! Every warm asset the serving stack accumulates — content-addressed
//! result entries (tier 0, the in-memory LRU of
//! [`crate::cache::ResultCache`]), the autotune winner table, memoized
//! launch plans — used to die with the process. This module adds the
//! tier below: a [`Sink`] (put/get/delete/len/iter over content-addressed
//! [`StoreKey`]s reusing the result cache's 128-bit dual-FNV digest) with
//! two implementations, [`MemorySink`] and the durable [`FsSink`]
//! (per-entry files with a checksummed header, atomic
//! temp-file + rename writes, rebuild-on-open index).
//!
//! Layering ([`crate::cache`] is the front, this module is the back):
//!
//! * **Write-through** — every stored result is also persisted, so a
//!   restart on the same `--store-dir` serves repeats with zero backend
//!   launches and bit-identical bytes.
//! * **Spill, not evict** — when the result cache's byte budget forces
//!   an entry out of memory, a disk copy is retained (the `spills`
//!   counter): the budget demotes entries to tier 1 instead of deleting
//!   work.
//! * **Lazy load** — a memory miss consults the store
//!   ([`load_result`]); a checksum-verified entry is promoted back into
//!   tier 0 (the `loads` counter). A torn or corrupt entry is a typed
//!   [`MatexpError::Store`] at the sink layer and a counted miss here —
//!   wrong bits are never served.
//! * **Artifacts** — the autotune table and plan-cache entries persist
//!   in the same store ([`persist_autotune`], [`persist_plan`]) and are
//!   re-injected on [`configure`], so a warm restart skips startup
//!   probing and planning.
//! * **Cluster pull** — [`export_hot`] / [`install`] move artifacts over
//!   the `cluster` wire op so a joining member warms up from the
//!   router's owner members (see [`crate::cluster`]).
//!
//! Enabled per deployment with [`crate::config::StoreSettings`] /
//! `--store-dir DIR` / `--store-budget-mb M`; disabled (no persistence,
//! all counters zero) by default.

pub mod codec;
pub mod fs;
pub mod memory;

pub use fs::FsSink;
pub use memory::MemorySink;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::cache::{CachedExpm, PlanKey, ResultKey};
use crate::config::StoreSettings;
use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::json_obj;
use crate::linalg::matrix::Matrix;
use crate::plan::PlanKind;
use crate::util::json::Json;

/// Artifact namespace of a [`StoreKey`] — which codec its payload speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// A cached exponentiation result (key + matrix payload).
    Result,
    /// The autotune winner table (one well-known entry).
    Autotune,
    /// One memoized launch plan.
    Plan,
}

impl ArtifactKind {
    /// Wire/header tag byte.
    pub fn tag(self) -> u8 {
        match self {
            ArtifactKind::Result => 0,
            ArtifactKind::Autotune => 1,
            ArtifactKind::Plan => 2,
        }
    }

    /// Inverse of [`ArtifactKind::tag`].
    pub fn from_tag(tag: u8) -> Option<ArtifactKind> {
        match tag {
            0 => Some(ArtifactKind::Result),
            1 => Some(ArtifactKind::Autotune),
            2 => Some(ArtifactKind::Plan),
            _ => None,
        }
    }

    /// Canonical lowercase name (cluster wire vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            ArtifactKind::Result => "result",
            ArtifactKind::Autotune => "autotune",
            ArtifactKind::Plan => "plan",
        }
    }

    /// Inverse of [`ArtifactKind::as_str`].
    pub fn from_str_opt(s: &str) -> Option<ArtifactKind> {
        match s {
            "result" => Some(ArtifactKind::Result),
            "autotune" => Some(ArtifactKind::Autotune),
            "plan" => Some(ArtifactKind::Plan),
            _ => None,
        }
    }
}

/// Content address of one store entry: an artifact namespace plus the
/// 128-bit dual-FNV digest the result cache already computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StoreKey {
    /// Which codec the payload speaks.
    pub kind: ArtifactKind,
    /// High 64 bits of the content digest.
    pub hi: u64,
    /// Low 64 bits of the content digest.
    pub lo: u64,
}

impl StoreKey {
    /// Canonical hex form, also the [`FsSink`] file stem:
    /// `{kind_tag:02x}-{hi:016x}{lo:016x}`.
    pub fn hex(&self) -> String {
        format!("{:02x}-{:016x}{:016x}", self.kind.tag(), self.hi, self.lo)
    }
}

/// XXH64-style checksum (hand-rolled like the rest of the crate): 8-byte
/// lane folding with prime multiplies and rotates, finished with an
/// avalanche mix, seeded by the input length so truncation always
/// changes the sum.
pub fn checksum(bytes: &[u8]) -> u64 {
    const P1: u64 = 0x9E37_79B1_85EB_CA87;
    const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    const P3: u64 = 0x1656_67B1_9E37_79F9;
    let mut h = P3 ^ (bytes.len() as u64);
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().expect("chunks_exact(8)"));
        h = (h ^ w.wrapping_mul(P2)).rotate_left(27).wrapping_mul(P1);
    }
    for &b in chunks.remainder() {
        h = (h ^ u64::from(b).wrapping_mul(P1)).rotate_left(11).wrapping_mul(P2);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(P2);
    h ^= h >> 29;
    h = h.wrapping_mul(P3);
    h ^= h >> 32;
    h
}

/// A pluggable persistence backend: a flat map from [`StoreKey`] to an
/// opaque payload. Implementations must be safe to share across the
/// serving threads.
///
/// The error contract carries the durability semantics: `get` answers
/// `Ok(None)` for an absent key but `Err(`[`MatexpError::Store`]`)` for
/// an entry that exists and fails verification (torn write, bit rot) —
/// a corrupt entry must be distinguishable from a miss and must never
/// decode to wrong bits. One entry's corruption must not affect any
/// other entry.
pub trait Sink: Send + Sync {
    /// Store `payload` under `key`, replacing any existing entry.
    /// Durable implementations must commit atomically: a crash mid-put
    /// leaves either the old entry or the new one, never a torn mix.
    fn put(&self, key: StoreKey, payload: &[u8]) -> Result<()>;

    /// The payload under `key`: `Ok(None)` when absent, a typed
    /// [`MatexpError::Store`] when present but corrupt.
    fn get(&self, key: &StoreKey) -> Result<Option<Vec<u8>>>;

    /// Remove the entry; `Ok(true)` when something was removed.
    fn delete(&self, key: &StoreKey) -> Result<bool>;

    /// Number of entries currently held.
    fn len(&self) -> usize;

    /// `true` when the sink holds nothing.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every key currently held (index order, no payload I/O).
    fn keys(&self) -> Vec<StoreKey>;

    /// Total payload bytes currently held (headers not counted).
    fn bytes(&self) -> u64;

    /// Index-only membership test (no payload verification).
    fn contains(&self, key: &StoreKey) -> bool;
}

// ------------------------------------------------------------- counters

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);
static SPILLS: AtomicU64 = AtomicU64::new(0);
static LOADS: AtomicU64 = AtomicU64::new(0);

/// Point-in-time totals for the persistence tier (process-wide; zeros
/// when no store is configured).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Store lookups that found a verified entry.
    pub hits: u64,
    /// Store lookups that found nothing — or found a corrupt entry,
    /// which is served as a miss, never as wrong bits.
    pub misses: u64,
    /// Result entries demoted from the in-memory tier by its byte budget
    /// with a disk copy retained (spill-instead-of-evict).
    pub spills: u64,
    /// Entries loaded out of the store back into a warm tier (results
    /// promoted on miss, artifacts re-injected on warm restart).
    pub loads: u64,
    /// Entries currently held by the active sink.
    pub entries: u64,
    /// Payload bytes currently held by the active sink.
    pub bytes: u64,
}

impl StoreCounters {
    /// Serialize for the server `metrics` response.
    pub fn to_json(&self) -> Json {
        json_obj![
            ("hits", self.hits),
            ("misses", self.misses),
            ("spills", self.spills),
            ("loads", self.loads),
            ("entries", self.entries),
            ("bytes", self.bytes),
        ]
    }
}

/// Snapshot the process-wide store counters.
pub fn counters() -> StoreCounters {
    let (entries, bytes) = match active() {
        Some(store) => (store.sink.len() as u64, store.sink.bytes()),
        None => (0, 0),
    };
    StoreCounters {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
        spills: SPILLS.load(Ordering::Relaxed),
        loads: LOADS.load(Ordering::Relaxed),
        entries,
        bytes,
    }
}

// ------------------------------------------------------- the active store

/// The artifact store the process serves from: a [`Sink`] behind a disk
/// byte budget with FIFO demotion (oldest committed entries deleted
/// first when a put would exceed the budget).
pub struct ArtifactStore {
    sink: Box<dyn Sink>,
    budget: u64,
    /// The directory this store serves (None for memory-backed stores) —
    /// lets [`configure`] recognize an already-active directory.
    dir: Option<std::path::PathBuf>,
    /// Commit order for budget-driven deletion (rebuilt in arbitrary
    /// index order when a sink is reopened).
    order: Mutex<VecDeque<StoreKey>>,
}

impl ArtifactStore {
    /// Wrap `sink` under `budget_bytes` of payload budget.
    pub fn with_sink(sink: Box<dyn Sink>, budget_bytes: u64) -> ArtifactStore {
        let order = sink.keys().into();
        ArtifactStore { sink, budget: budget_bytes, dir: None, order: Mutex::new(order) }
    }

    /// Open the store `settings` describes: an [`FsSink`] rooted at
    /// `settings.dir` (which must be set).
    pub fn open(settings: &StoreSettings) -> Result<ArtifactStore> {
        let dir = settings.dir.as_ref().ok_or_else(|| {
            MatexpError::Store("store.dir is not set — nothing to open".into())
        })?;
        let sink = FsSink::open(dir)?;
        let mut store = ArtifactStore::with_sink(Box::new(sink), settings.budget_bytes());
        store.dir = Some(dir.clone());
        Ok(store)
    }

    /// The directory this store serves, when filesystem-backed.
    pub fn dir(&self) -> Option<&std::path::Path> {
        self.dir.as_deref()
    }

    /// The sink behind this store.
    pub fn sink(&self) -> &dyn Sink {
        self.sink.as_ref()
    }

    /// Store `payload` under `key`, deleting oldest entries to respect
    /// the byte budget. A payload bigger than the whole budget is
    /// dropped rather than flushing everything else.
    pub fn put(&self, key: StoreKey, payload: &[u8]) -> Result<()> {
        let need = payload.len() as u64;
        if need > self.budget {
            return Ok(());
        }
        let mut order = self.order.lock().expect("store order poisoned");
        while self.sink.bytes() + need > self.budget {
            match order.pop_front() {
                Some(old) if old != key => {
                    self.sink.delete(&old)?;
                }
                Some(_) => {} // replacing this key frees its own bytes
                None => break,
            }
        }
        let fresh = !self.sink.contains(&key);
        self.sink.put(key, payload)?;
        if fresh {
            order.push_back(key);
        }
        Ok(())
    }

    /// The verified payload under `key`. Counts a hit or a miss; a
    /// corrupt entry counts as a miss and is deleted so a later
    /// write-through can replace it — its typed [`MatexpError::Store`]
    /// stays observable at the [`Sink`] layer.
    pub fn get(&self, key: &StoreKey) -> Option<Vec<u8>> {
        match self.sink.get(key) {
            Ok(Some(payload)) => {
                HITS.fetch_add(1, Ordering::Relaxed);
                Some(payload)
            }
            Ok(None) => {
                MISSES.fetch_add(1, Ordering::Relaxed);
                None
            }
            Err(_) => {
                MISSES.fetch_add(1, Ordering::Relaxed);
                let _ = self.sink.delete(key);
                self.order.lock().expect("store order poisoned").retain(|k| k != key);
                None
            }
        }
    }

    /// Index-only membership test.
    pub fn contains(&self, key: &StoreKey) -> bool {
        self.sink.contains(key)
    }
}

fn active_slot() -> &'static Mutex<Option<Arc<ArtifactStore>>> {
    static ACTIVE: OnceLock<Mutex<Option<Arc<ArtifactStore>>>> = OnceLock::new();
    ACTIVE.get_or_init(|| Mutex::new(None))
}

/// The process-wide store, when one is configured.
pub fn active() -> Option<Arc<ArtifactStore>> {
    active_slot().lock().expect("store slot poisoned").clone()
}

/// Install `store` as the process-wide instance (tests and embedders;
/// deployments go through [`configure`]). Replaces any previous one.
pub fn activate(store: Arc<ArtifactStore>) {
    *active_slot().lock().expect("store slot poisoned") = Some(store);
}

/// Drop the process-wide store (persisted entries stay on disk).
pub fn deactivate() {
    *active_slot().lock().expect("store slot poisoned") = None;
}

/// Configure the process-wide store from `settings` and warm-load its
/// persisted artifacts (autotune rows, plans) into their tiers. With no
/// `settings.dir` this is a no-op; engine/coordinator construction calls
/// it so `--store-dir` alone turns the tier on. Returns how many
/// artifacts were warm-loaded.
pub fn configure(settings: &StoreSettings) -> Result<usize> {
    let Some(dir) = settings.dir.as_ref() else { return Ok(0) };
    if let Some(current) = active() {
        // already serving this directory: reconfiguring per-worker is a no-op
        if current.dir() == Some(dir.as_path()) {
            return Ok(0);
        }
    }
    let store = Arc::new(ArtifactStore::open(settings)?);
    let loaded = warm_load(&store);
    activate(store);
    Ok(loaded)
}

/// Re-inject persisted artifacts into their warm tiers: autotune rows
/// into the tuning table, plans into the plan cache. Result entries stay
/// lazy — they promote on first lookup. Returns the artifact count.
fn warm_load(store: &ArtifactStore) -> usize {
    let mut loaded = 0;
    for key in store.sink.keys() {
        let payload = match key.kind {
            ArtifactKind::Result => continue,
            _ => match store.sink.get(&key) {
                Ok(Some(p)) => p,
                _ => continue, // torn/corrupt artifacts are skipped, not fatal
            },
        };
        match key.kind {
            ArtifactKind::Autotune => {
                if let Ok(rows) = codec::decode_autotune(&payload) {
                    for (n, winner, secs) in rows {
                        crate::linalg::autotune::record(n, &[(winner, secs)]);
                        loaded += 1;
                    }
                    LOADS.fetch_add(1, Ordering::Relaxed);
                }
            }
            ArtifactKind::Plan => {
                if let Ok((plan_key, plan)) = codec::decode_plan(&payload) {
                    crate::cache::PlanCache::global().fetch(
                        plan_key,
                        crate::cache::CacheControl::Use,
                        || plan,
                    );
                    LOADS.fetch_add(1, Ordering::Relaxed);
                    loaded += 1;
                }
            }
            ArtifactKind::Result => unreachable!("skipped above"),
        }
    }
    loaded
}

// --------------------------------------------- tier plumbing (results)

/// Write-through persist one result entry (no-op without an active
/// store, or when the entry is already on disk).
pub fn persist_result(
    key: &ResultKey,
    result: &Matrix,
    method: Method,
    plan_kind: Option<PlanKind>,
) {
    let Some(store) = active() else { return };
    let skey = codec::result_store_key(key);
    if store.contains(&skey) {
        return;
    }
    let payload = codec::encode_result(key, result, method, plan_kind);
    let _ = store.put(skey, &payload);
}

/// Record a budget-driven demotion from the memory tier: ensure the
/// entry has a disk copy and count the spill.
pub fn spill_result(key: &ResultKey, value: &CachedExpm) {
    if active().is_none() {
        return;
    }
    persist_result(key, &value.result, value.method, value.plan_kind);
    SPILLS.fetch_add(1, Ordering::Relaxed);
}

/// Tier-1 lookup on a memory miss: fetch, verify and decode the entry,
/// promote it back into the in-memory result cache, count the load.
/// `None` on absence or corruption (wrong bits are never served).
pub fn load_result(key: &ResultKey) -> Option<CachedExpm> {
    let store = active()?;
    let payload = store.get(&codec::result_store_key(key))?;
    let (stored_key, value) = codec::decode_result(&payload).ok()?;
    if stored_key != *key {
        // digest collision or cross-wired entry: never serve it
        return None;
    }
    crate::cache::ResultCache::global().insert(
        stored_key,
        &value.result,
        value.method,
        value.plan_kind,
    );
    LOADS.fetch_add(1, Ordering::Relaxed);
    Some(value)
}

// -------------------------------------------- tier plumbing (artifacts)

/// Persist the current autotune winner table as one artifact (no-op
/// without an active store or with an empty table).
pub fn persist_autotune() {
    let Some(store) = active() else { return };
    let rows = crate::linalg::autotune::snapshot();
    if rows.is_empty() {
        return;
    }
    let payload = codec::encode_autotune(&rows);
    let _ = store.put(codec::autotune_store_key(), &payload);
}

/// Write-through persist one memoized plan (no-op without an active
/// store, or when already persisted).
pub fn persist_plan(key: &PlanKey, plan: &crate::plan::Plan) {
    let Some(store) = active() else { return };
    let skey = codec::plan_store_key(key);
    if store.contains(&skey) {
        return;
    }
    let payload = codec::encode_plan(key, plan);
    let _ = store.put(skey, &payload);
}

// ------------------------------------------------- cluster artifact pull

/// How many hot result entries [`export_hot`] ships at most (the
/// recency-ordered head of the memory tier).
pub const HOT_EXPORT_LIMIT: usize = 32;

/// Export this process's hot artifacts as a wire document: the most
/// recently used result entries plus the autotune table, each payload
/// base64-encoded in its store codec. What a cluster member answers to
/// the `cluster pull` op.
pub fn export_hot(limit: usize) -> Json {
    let mut artifacts = Vec::new();
    for (key, value) in crate::cache::ResultCache::global().export_recent(limit) {
        let payload = codec::encode_result(&key, &value.result, value.method, value.plan_kind);
        artifacts.push(json_obj![
            ("kind", ArtifactKind::Result.as_str()),
            ("payload", crate::util::base64::encode(&payload)),
        ]);
    }
    let rows = crate::linalg::autotune::snapshot();
    if !rows.is_empty() {
        artifacts.push(json_obj![
            ("kind", ArtifactKind::Autotune.as_str()),
            ("payload", crate::util::base64::encode(&codec::encode_autotune(&rows))),
        ]);
    }
    Json::Arr(artifacts)
}

/// Install artifacts from a wire document (the array [`export_hot`]
/// produces, or an object holding it under `"artifacts"`) into the local
/// warm tiers and the active store. Undecodable entries are skipped.
/// Returns how many artifacts were installed.
pub fn install(doc: &Json) -> usize {
    let arr = match doc.as_arr() {
        Some(a) => a,
        None => match doc.get("artifacts").and_then(Json::as_arr) {
            Some(a) => a,
            None => return 0,
        },
    };
    let mut installed = 0;
    for entry in arr {
        let kind = entry
            .get("kind")
            .and_then(Json::as_str)
            .and_then(ArtifactKind::from_str_opt);
        let payload = entry
            .get("payload")
            .and_then(Json::as_str)
            .and_then(crate::util::base64::decode);
        let (Some(kind), Some(payload)) = (kind, payload) else { continue };
        match kind {
            ArtifactKind::Result => {
                if let Ok((key, value)) = codec::decode_result(&payload) {
                    crate::cache::ResultCache::global().insert(
                        key,
                        &value.result,
                        value.method,
                        value.plan_kind,
                    );
                    persist_result(&key, &value.result, value.method, value.plan_kind);
                    installed += 1;
                }
            }
            ArtifactKind::Autotune => {
                if let Ok(rows) = codec::decode_autotune(&payload) {
                    for (n, winner, secs) in &rows {
                        crate::linalg::autotune::record(*n, &[(*winner, *secs)]);
                    }
                    persist_autotune();
                    installed += 1;
                }
            }
            ArtifactKind::Plan => {
                if let Ok((plan_key, plan)) = codec::decode_plan(&payload) {
                    let stored = crate::cache::PlanCache::global().fetch(
                        plan_key,
                        crate::cache::CacheControl::Use,
                        || plan,
                    );
                    persist_plan(&plan_key, &stored);
                    installed += 1;
                }
            }
        }
    }
    installed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_is_deterministic_and_sensitive() {
        let data: Vec<u8> = (0u8..=200).collect();
        assert_eq!(checksum(&data), checksum(&data));
        let mut flipped = data.clone();
        flipped[37] ^= 0x01;
        assert_ne!(checksum(&data), checksum(&flipped), "single bit flip changes the sum");
        assert_ne!(checksum(&data), checksum(&data[..data.len() - 1]), "truncation changes it");
        assert_ne!(checksum(b""), checksum(&[0]), "length is part of the seed");
    }

    #[test]
    fn artifact_kind_tags_roundtrip() {
        for kind in [ArtifactKind::Result, ArtifactKind::Autotune, ArtifactKind::Plan] {
            assert_eq!(ArtifactKind::from_tag(kind.tag()), Some(kind));
            assert_eq!(ArtifactKind::from_str_opt(kind.as_str()), Some(kind));
        }
        assert_eq!(ArtifactKind::from_tag(99), None);
        assert_eq!(ArtifactKind::from_str_opt("wat"), None);
    }

    #[test]
    fn artifact_store_budget_deletes_oldest_first() {
        let store = ArtifactStore::with_sink(Box::new(MemorySink::new()), 100);
        let key = |lo| StoreKey { kind: ArtifactKind::Result, hi: 7, lo };
        store.put(key(1), &[1u8; 40]).unwrap();
        store.put(key(2), &[2u8; 40]).unwrap();
        store.put(key(3), &[3u8; 40]).unwrap(); // 120 > 100: key(1) goes
        assert!(store.get(&key(1)).is_none());
        assert_eq!(store.get(&key(2)).unwrap(), vec![2u8; 40]);
        assert_eq!(store.get(&key(3)).unwrap(), vec![3u8; 40]);
        // oversized payloads are dropped, not budget-flushing
        store.put(key(4), &[4u8; 200]).unwrap();
        assert!(store.get(&key(4)).is_none());
        assert!(store.get(&key(2)).is_some());
    }
}
