//! Artifact payload codecs: the byte formats stored under each
//! [`ArtifactKind`], plus the content addressing that maps cache keys to
//! [`StoreKey`]s.
//!
//! All formats are little-endian, self-describing (the identifying key
//! is embedded in the payload, so a store entry can be verified against
//! the key that addressed it and shipped standalone over the cluster
//! wire), and strict: trailing bytes, short buffers, or non-canonical
//! tags all decode to the typed [`MatexpError::Store`] — a codec never
//! guesses.

use std::str::FromStr;

use crate::cache::result::KEY_BYTES;
use crate::cache::{CachedExpm, PlanKey, ResultKey};
use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::linalg::autotune::TuneRow;
use crate::linalg::expm::CpuAlgo;
use crate::linalg::matrix::Matrix;
use crate::plan::{Plan, PlanKind, Step};
use crate::store::{checksum, ArtifactKind, StoreKey};

fn bad(what: impl Into<String>) -> MatexpError {
    MatexpError::Store(format!("undecodable artifact: {}", what.into()))
}

/// Store address of one result entry: the [`ResultKey`]'s folded 128-bit
/// digest under [`ArtifactKind::Result`].
pub fn result_store_key(key: &ResultKey) -> StoreKey {
    let (hi, lo) = key.store_digest();
    StoreKey { kind: ArtifactKind::Result, hi, lo }
}

/// The well-known address of the (single) autotune-table artifact.
pub fn autotune_store_key() -> StoreKey {
    let hi = checksum(b"matexp autotune table");
    StoreKey { kind: ArtifactKind::Autotune, hi, lo: hi.rotate_left(32) }
}

/// Store address of one memoized plan, folding every [`PlanKey`] field.
pub fn plan_store_key(key: &PlanKey) -> StoreKey {
    const PRIME1: u64 = 0x0000_0100_0000_01b3;
    const PRIME2: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut hi = 0xcbf2_9ce4_8422_2325u64;
    let mut lo = 0x6c62_272e_07bb_0142u64;
    let words =
        [key.n as u64, key.power, u64::from(plan_kind_tag(key.kind)), key.method as u64];
    for w in words {
        hi = (hi ^ w).wrapping_mul(PRIME1);
        lo = (lo ^ w.rotate_left(32)).wrapping_mul(PRIME2);
    }
    StoreKey { kind: ArtifactKind::Plan, hi, lo }
}

// ------------------------------------------------------------- primitives

/// Strict little-endian reader over a payload slice.
struct Reader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Reader<'a> {
        Reader { bytes, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&end| end <= self.bytes.len())
            .ok_or_else(|| bad(format!("truncated at byte {} (wanted {n} more)", self.at)))?;
        let slice = &self.bytes[self.at..end];
        self.at = end;
        Ok(slice)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("sized")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Every byte must be consumed — trailing garbage is corruption.
    fn finish(self) -> Result<()> {
        if self.at == self.bytes.len() {
            Ok(())
        } else {
            Err(bad(format!("{} trailing bytes", self.bytes.len() - self.at)))
        }
    }
}

fn plan_kind_tag(kind: PlanKind) -> u8 {
    match kind {
        PlanKind::Naive => 0,
        PlanKind::Binary => 1,
        PlanKind::BinaryFused => 2,
        PlanKind::Chained => 3,
        PlanKind::AdditionChain => 4,
        PlanKind::Strassen => 5,
    }
}

fn plan_kind_from_tag(tag: u8) -> Result<PlanKind> {
    Ok(match tag {
        0 => PlanKind::Naive,
        1 => PlanKind::Binary,
        2 => PlanKind::BinaryFused,
        3 => PlanKind::Chained,
        4 => PlanKind::AdditionChain,
        5 => PlanKind::Strassen,
        other => return Err(bad(format!("unknown plan kind tag {other}"))),
    })
}

/// `Option<PlanKind>` as one byte; `NO_PLAN_KIND` encodes `None`.
const NO_PLAN_KIND: u8 = 255;

// ---------------------------------------------------------------- results

/// Result payload: embedded [`ResultKey`] bytes, the producing run's
/// plan-kind tag, then the matrix as raw f32 bit patterns (bit-exact for
/// NaN/±Inf/subnormals — no textual detour).
pub fn encode_result(
    key: &ResultKey,
    result: &Matrix,
    method: Method,
    plan_kind: Option<PlanKind>,
) -> Vec<u8> {
    let data = result.data();
    let mut out = Vec::with_capacity(KEY_BYTES + 2 + data.len() * 4);
    out.extend_from_slice(&key.to_bytes());
    out.push(method as u8);
    out.push(plan_kind.map_or(NO_PLAN_KIND, plan_kind_tag));
    for v in data {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// Inverse of [`encode_result`]; validates the matrix length against the
/// embedded key's dimension.
pub fn decode_result(payload: &[u8]) -> Result<(ResultKey, CachedExpm)> {
    let mut r = Reader::new(payload);
    let key = ResultKey::from_bytes(r.take(KEY_BYTES)?)
        .ok_or_else(|| bad("non-canonical result key"))?;
    let method_tag = r.u8()?;
    let method = *Method::all()
        .get(method_tag as usize)
        .ok_or_else(|| bad(format!("unknown method tag {method_tag}")))?;
    let plan_kind = match r.u8()? {
        NO_PLAN_KIND => None,
        tag => Some(plan_kind_from_tag(tag)?),
    };
    let n = key.n();
    let want = n
        .checked_mul(n)
        .and_then(|c| c.checked_mul(4))
        .ok_or_else(|| bad(format!("absurd matrix dimension {n}")))?;
    let raw = r.take(want)?;
    r.finish()?;
    let data: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().expect("sized"))))
        .collect();
    let result = Matrix::from_vec(n, data)
        .map_err(|e| bad(format!("matrix rebuild failed: {e}")))?;
    Ok((key, CachedExpm { result, method, plan_kind }))
}

// --------------------------------------------------------------- autotune

/// Autotune-table payload: row count, then per row the probed size, the
/// winner's canonical name (length-prefixed) and its best-of-probes
/// seconds as f64 bits. `gflops` is derived state —
/// [`crate::linalg::autotune::record`] recomputes it on restore.
pub fn encode_autotune(rows: &[TuneRow]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(rows.len() as u64).to_le_bytes());
    for row in rows {
        out.extend_from_slice(&(row.n as u64).to_le_bytes());
        let name = row.winner.name().as_bytes();
        out.push(name.len() as u8);
        out.extend_from_slice(name);
        out.extend_from_slice(&row.secs.to_bits().to_le_bytes());
    }
    out
}

/// Inverse of [`encode_autotune`]: `(n, winner, secs)` triples ready for
/// [`crate::linalg::autotune::record`].
pub fn decode_autotune(payload: &[u8]) -> Result<Vec<(usize, CpuAlgo, f64)>> {
    let mut r = Reader::new(payload);
    let count = r.u64()?;
    if count > 1 << 20 {
        return Err(bad(format!("absurd autotune row count {count}")));
    }
    let mut rows = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let n = r.u64()? as usize;
        let name_len = r.u8()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .map_err(|_| bad("non-utf8 algo name"))?;
        let winner =
            CpuAlgo::from_str(name).map_err(|_| bad(format!("unknown algo {name:?}")))?;
        let secs = r.f64()?;
        if !(secs.is_finite() && secs > 0.0) {
            return Err(bad(format!("non-positive probe time {secs}")));
        }
        rows.push((n, winner, secs));
    }
    r.finish()?;
    Ok(rows)
}

// ------------------------------------------------------------------ plans

const STEP_COPY: u8 = 0;
const STEP_MUL: u8 = 1;
const STEP_SQMUL: u8 = 2;
const STEP_SQUARE_CHAIN: u8 = 3;

/// Plan payload: the full [`PlanKey`] (n, power, kind, method), the
/// plan's register-file shape, then every step as a tagged record.
pub fn encode_plan(key: &PlanKey, plan: &Plan) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + plan.steps.len() * 25);
    out.extend_from_slice(&(key.n as u64).to_le_bytes());
    out.extend_from_slice(&key.power.to_le_bytes());
    out.push(plan_kind_tag(key.kind));
    out.push(key.method as u8);
    out.extend_from_slice(&plan.power.to_le_bytes());
    out.push(plan_kind_tag(plan.kind));
    out.extend_from_slice(&(plan.n_regs as u64).to_le_bytes());
    out.extend_from_slice(&(plan.result as u64).to_le_bytes());
    out.extend_from_slice(&(plan.steps.len() as u64).to_le_bytes());
    for step in &plan.steps {
        match *step {
            Step::Copy { dst, src } => {
                out.push(STEP_COPY);
                out.extend_from_slice(&(dst as u64).to_le_bytes());
                out.extend_from_slice(&(src as u64).to_le_bytes());
            }
            Step::Mul { dst, lhs, rhs } => {
                out.push(STEP_MUL);
                out.extend_from_slice(&(dst as u64).to_le_bytes());
                out.extend_from_slice(&(lhs as u64).to_le_bytes());
                out.extend_from_slice(&(rhs as u64).to_le_bytes());
            }
            Step::SqMul { acc, base } => {
                out.push(STEP_SQMUL);
                out.extend_from_slice(&(acc as u64).to_le_bytes());
                out.extend_from_slice(&(base as u64).to_le_bytes());
            }
            Step::SquareChain { reg, k } => {
                out.push(STEP_SQUARE_CHAIN);
                out.extend_from_slice(&(reg as u64).to_le_bytes());
                out.extend_from_slice(&u64::from(k).to_le_bytes());
            }
        }
    }
    out
}

/// Inverse of [`encode_plan`].
pub fn decode_plan(payload: &[u8]) -> Result<(PlanKey, Plan)> {
    let mut r = Reader::new(payload);
    let n = r.u64()? as usize;
    let power = r.u64()?;
    let kind = plan_kind_from_tag(r.u8()?)?;
    let method_tag = r.u8()?;
    let method = *Method::all()
        .get(method_tag as usize)
        .ok_or_else(|| bad(format!("unknown method tag {method_tag}")))?;
    let key = PlanKey { n, power, kind, method };
    let plan_power = r.u64()?;
    let plan_kind = plan_kind_from_tag(r.u8()?)?;
    let n_regs = r.u64()? as usize;
    let result = r.u64()? as usize;
    let step_count = r.u64()?;
    if step_count > 1 << 24 {
        return Err(bad(format!("absurd step count {step_count}")));
    }
    let mut steps = Vec::with_capacity(step_count as usize);
    for _ in 0..step_count {
        let step = match r.u8()? {
            STEP_COPY => {
                Step::Copy { dst: r.u64()? as usize, src: r.u64()? as usize }
            }
            STEP_MUL => Step::Mul {
                dst: r.u64()? as usize,
                lhs: r.u64()? as usize,
                rhs: r.u64()? as usize,
            },
            STEP_SQMUL => {
                Step::SqMul { acc: r.u64()? as usize, base: r.u64()? as usize }
            }
            STEP_SQUARE_CHAIN => {
                let reg = r.u64()? as usize;
                let k = u32::try_from(r.u64()?)
                    .map_err(|_| bad("square-chain length overflows u32"))?;
                Step::SquareChain { reg, k }
            }
            other => return Err(bad(format!("unknown step tag {other}"))),
        };
        steps.push(step);
    }
    r.finish()?;
    let plan = Plan { power: plan_power, kind: plan_kind, steps, n_regs, result };
    plan.validate().map_err(|e| bad(format!("restored plan is invalid: {e}")))?;
    Ok((key, plan))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_payload_roundtrips_bit_exactly_including_non_finite() {
        let mut m = Matrix::random(6, 3);
        m.set(0, 0, f32::NAN);
        m.set(0, 1, f32::INFINITY);
        m.set(1, 0, f32::NEG_INFINITY);
        m.set(1, 1, f32::MIN_POSITIVE / 2.0); // subnormal
        m.set(2, 2, -0.0);
        let key = ResultKey::for_parts(&m, 64, Method::Ours, Some(1e-4));
        let payload = encode_result(&key, &m, Method::Ours, Some(PlanKind::Chained));
        let (got_key, got) = decode_result(&payload).expect("decodes");
        assert_eq!(got_key, key);
        assert_eq!(got.method, Method::Ours);
        assert_eq!(got.plan_kind, Some(PlanKind::Chained));
        let same = m.data().iter().zip(got.result.data()).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "payload must be bit-identical, NaN and ±Inf included");
    }

    #[test]
    fn result_decode_rejects_damage() {
        let m = Matrix::random(4, 9);
        let key = ResultKey::for_parts(&m, 8, Method::Ours, None);
        let payload = encode_result(&key, &m, Method::Ours, None);
        // every truncation boundary fails
        for cut in 0..payload.len() {
            assert!(decode_result(&payload[..cut]).is_err(), "truncation at {cut}");
        }
        // trailing garbage fails
        let mut long = payload.clone();
        long.push(0);
        assert!(decode_result(&long).is_err());
        // a bad plan-kind tag fails (byte after key + method)
        let mut bad_tag = payload.clone();
        bad_tag[KEY_BYTES + 1] = 77;
        assert!(decode_result(&bad_tag).is_err());
    }

    #[test]
    fn autotune_rows_roundtrip() {
        let rows = vec![
            TuneRow { n: 64, winner: CpuAlgo::Blocked, secs: 1e-4, gflops: 0.0 },
            TuneRow { n: 256, winner: CpuAlgo::Ikj, secs: 2.5e-3, gflops: 0.0 },
        ];
        let payload = encode_autotune(&rows);
        let got = decode_autotune(&payload).expect("decodes");
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (64, CpuAlgo::Blocked, 1e-4));
        assert_eq!(got[1], (256, CpuAlgo::Ikj, 2.5e-3));
        for cut in 0..payload.len() {
            assert!(decode_autotune(&payload[..cut]).is_err(), "truncation at {cut}");
        }
    }

    #[test]
    fn plans_roundtrip_across_every_planner() {
        let plans = [
            (PlanKind::Naive, Plan::naive(7)),
            (PlanKind::Binary, Plan::binary(100, false)),
            (PlanKind::BinaryFused, Plan::binary(100, true)),
            (PlanKind::Chained, Plan::chained(1000, &[4, 2])),
            (PlanKind::AdditionChain, Plan::addition_chain(511)),
            (PlanKind::Strassen, Plan::strassen(64)),
        ];
        for (kind, plan) in plans {
            let key = PlanKey { n: 128, power: plan.power, kind, method: Method::Ours };
            let payload = encode_plan(&key, &plan);
            let (got_key, got) = decode_plan(&payload).expect("decodes");
            assert_eq!(got_key, key);
            assert_eq!(got, plan, "plan {kind:?} must roundtrip exactly");
        }
    }

    #[test]
    fn store_addresses_are_distinct_per_key() {
        let m = Matrix::random(8, 1);
        let a = result_store_key(&ResultKey::for_parts(&m, 64, Method::Ours, None));
        let b = result_store_key(&ResultKey::for_parts(&m, 65, Method::Ours, None));
        assert_ne!((a.hi, a.lo), (b.hi, b.lo));
        assert_eq!(a.kind, ArtifactKind::Result);
        let p1 = plan_store_key(&PlanKey {
            n: 64,
            power: 100,
            kind: PlanKind::Binary,
            method: Method::Ours,
        });
        let p2 = plan_store_key(&PlanKey {
            n: 64,
            power: 101,
            kind: PlanKind::Binary,
            method: Method::Ours,
        });
        assert_ne!((p1.hi, p1.lo), (p2.hi, p2.lo));
        assert_eq!(autotune_store_key(), autotune_store_key());
    }
}
