//! API-surface stub of the `xla` crate (xla-rs / PJRT bindings).
//!
//! This exists so `cargo build --features xla` type-checks and links
//! without network access or a PJRT plugin: it mirrors exactly the slice
//! of the xla-rs API that `matexp::runtime::pjrt` uses, and every runtime
//! entry point returns [`Error::Stub`]. To run on real PJRT, point the
//! `xla` path dependency in `rust/Cargo.toml` at an xla-rs checkout — the
//! `matexp` code is written against the real API and needs no changes.

use std::rc::Rc;

/// The single error the stub produces (plus a message slot so call sites
/// that construct errors still work against the real crate's `Display`).
#[derive(Debug)]
pub enum Error {
    Stub,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(
            "xla stub: built against rust/xla-stub; point the `xla` dependency at a real xla-rs checkout to use PJRT",
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Stub)
}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Device buffer (stub: uninhabitable at runtime).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Things `execute`/`execute_b` accept as arguments.
pub trait ExecuteArg {}
impl ExecuteArg for Literal {}
impl ExecuteArg for Rc<PjRtBuffer> {}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<A: ExecuteArg>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }

    pub fn execute_b<A: ExecuteArg>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn platform_version(&self) -> String {
        "0".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_errors() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1, 1]).is_err());
        let msg = Error::Stub.to_string();
        assert!(msg.contains("xla-rs"), "{msg}");
    }
}
