//! `cargo bench --bench table3` — regenerates paper Table 3
//! (n=128) and Figures 7 and 8: paper vs simulated vs measured.
//!
//! Requires `make artifacts`; without them the bench still prints the
//! paper + simulated columns (measured shows "-").

use matexp::bench::Runner;
use matexp::config::MatexpConfig;
use matexp::experiments::{report, run_table};
use matexp::runtime::artifacts::ArtifactRegistry;

fn main() {
    let cfg = MatexpConfig::default();
    let registry = ArtifactRegistry::discover(&cfg.artifacts_dir).ok();
    if registry.is_none() {
        eprintln!("note: artifacts missing; printing paper+simulated columns only");
    }
    let t = run_table(3, &cfg, registry.as_ref()).expect("table 3");
    print!("{}", report::render_table(&t));
    print!("{}", report::render_figures(&t));

    // classic bench table over the measured cells
    let mut runner = Runner::new("table3 (n=128) measured cells");
    for c in &t.cells {
        if let Some(m) = c.measured {
            runner.record(&format!("n{}/N{}/naive-gpu", c.n, c.power), m.naive_gpu_s);
            runner.record(&format!("n{}/N{}/seq-cpu(extrap)", c.n, c.power), m.seq_cpu_s);
            runner.record(&format!("n{}/N{}/ours", c.n, c.power), m.ours_s);
        }
    }
    runner.report();
}
