//! `cargo bench --bench kernel_tier` — ablation A7: the raw-speed CPU
//! kernel tier (packed / simd / strassen) against the earlier matmul
//! variants at the paper's sizes n ∈ {256, 512, 1024}.
//!
//! Beyond the sampled per-kernel timings, this bench asserts the tier's
//! speedup contract at n=1024: the best new kernel must beat the
//! `blocked` baseline by ≥2× in release builds with the `simd` feature,
//! by ≥1× (never slower) with the scalar-packed fallback, and by a
//! relaxed 0.2× floor in debug builds (where only the plumbing, not the
//! codegen, is under test).

use matexp::bench::{BenchConfig, Runner};
use matexp::experiments::{ablations, report};
use matexp::linalg::matrix::Matrix;
use matexp::linalg::{packed, CpuAlgo};
use std::time::Duration;

const SIZES: [usize; 3] = [256, 512, 1024];

fn main() {
    let seed = 42u64;
    let mut runner = Runner::with_config(
        "CPU kernel tier",
        BenchConfig {
            warmup_iters: 1,
            min_samples: 3,
            max_samples: 10,
            time_budget: Duration::from_secs(30),
        },
    );
    for n in SIZES {
        let a = Matrix::random_spectral(n, 0.99, seed);
        let b = Matrix::random_spectral(n, 0.99, seed ^ 1);
        for algo in CpuAlgo::all() {
            if algo == CpuAlgo::Auto {
                continue; // dispatch row: duplicates whichever kernel wins
            }
            let mm = algo.matmul();
            runner.bench(&format!("matmul/{}/n{n}", algo.name()), || {
                matexp::bench::black_box(&mm(&a, &b));
            });
        }
    }
    runner.report();

    // the A7 table per size, plus the speedup contract at n=1024
    for n in SIZES {
        let arms = ablations::kernel_tier(n, seed);
        print!("{}", report::render_ablation(&format!("A7 kernel tier (n={n})"), &arms));
        println!();
        if n != 1024 {
            continue;
        }
        let wall = |name: &str| {
            arms.iter()
                .find(|x| x.name == name)
                .unwrap_or_else(|| panic!("{name} missing from the kernel tier"))
                .wall_s
        };
        let blocked = wall("blocked");
        let tier = wall("packed").min(wall("simd")).min(wall("strassen"));
        let speedup = blocked / tier.max(f64::MIN_POSITIVE);
        let floor = if cfg!(debug_assertions) {
            0.2
        } else if packed::simd_active() {
            2.0
        } else {
            1.0
        };
        println!("kernel tier speedup at n=1024: {speedup:.2}x vs blocked (floor {floor}x)");
        assert!(
            speedup >= floor,
            "kernel tier regressed: {speedup:.2}x < {floor}x vs blocked at n=1024"
        );
    }
}
