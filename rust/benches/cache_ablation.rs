//! `cargo bench --bench cache_ablation` — ablation A6: the three cache
//! tiers (plan / prepared-executable / result) quantified per request.
//!
//! * setup path: cold planner+prepare vs plan-warm, execution elided;
//! * result tier: modeled calibrated-C2050 cold execution vs the
//!   measured warm serve (content digest + LRU hit + result copy);
//! * full engine: measured cold / plan-warm / result-warm serves.

use matexp::config::MatexpConfig;
use matexp::experiments::{ablations, report};

fn main() {
    let cfg = MatexpConfig::default();
    let iters = 4000;

    for n in [256usize, 512, 1024] {
        let power = 1024;
        let setup = ablations::cache_setup_arms(n, power, iters);
        print!(
            "{}",
            report::render_ablation(
                &format!("A6 cache setup path (n={n}, N={power}, {iters} requests)"),
                &setup
            )
        );
        println!(
            "plan-warm setup speedup: {:.1}x\n",
            setup[0].wall_s / setup[1].wall_s.max(f64::MIN_POSITIVE)
        );

        let tiers = ablations::cache_result_arms(n, power, cfg.seed);
        print!(
            "{}",
            report::render_ablation(&format!("A6 result tier (n={n}, N={power})"), &tiers)
        );
        println!(
            "result-warm serving speedup vs modeled cold: {:.0}x\n",
            tiers[0].wall_s / tiers[1].wall_s.max(f64::MIN_POSITIVE)
        );
    }

    // measured engine arms at a size a bench run can afford end-to-end
    let arms = ablations::cache_engine_arms(&cfg, 256, 512).expect("engine arms");
    print!(
        "{}",
        report::render_ablation("A6 cache, full engine (n=256, N=512, measured serves)", &arms)
    );
    println!(
        "measured result-warm speedup: {:.0}x",
        arms[0].wall_s / arms[2].wall_s.max(f64::MIN_POSITIVE)
    );
}
