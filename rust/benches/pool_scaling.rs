//! `cargo bench --bench pool_scaling` — the full pool scaling experiment:
//! the Table-4 workload at n=1024 on 1/2/4/8 simulated C2050s plus the
//! heterogeneous CPU+sim arm, predicted AND measured (sim clocks are
//! simulated; numerics are real, so this wants a release build).

use matexp::bench::Runner;
use matexp::config::MatexpConfig;
use matexp::experiments::{render_scaling, run_pool_scaling, scaling};

fn main() {
    let cfg = MatexpConfig::default();
    let arms = scaling::default_scaling_arms();
    let t = run_pool_scaling(&cfg, 1024, &arms, true).expect("pool scaling");
    print!("{}", render_scaling(&t));

    let mut runner = Runner::new("pool scaling (n=1024, Table-4 workload)");
    runner.record("single-sim/workload", t.baseline_measured_s.unwrap_or(0.0));
    for arm in &t.arms {
        if let Some(m) = arm.measured_s {
            runner.record(&format!("{}/workload", arm.name), m);
        }
        if let Some(m) = arm.shard_measured_s {
            runner.record(&format!("{}/shard-N512", arm.name), m);
        }
    }
    runner.report();
}
