//! `cargo bench --bench planner` — the L3 hot-path microbenches: plan
//! construction, plan replay bookkeeping, and the CPU matmul variants
//! (ablation A4). None of these touch PJRT, so this target pinpoints
//! coordinator-side overhead in isolation.

use matexp::bench::{black_box, BenchConfig, Runner};
use matexp::experiments::{ablations, report};
use matexp::plan::Plan;
use std::time::Duration;

fn main() {
    let mut runner = Runner::with_config(
        "planner microbenches",
        BenchConfig {
            warmup_iters: 10,
            min_samples: 30,
            max_samples: 200,
            time_budget: Duration::from_secs(3),
        },
    );

    // plan construction across the paper's exponent range and beyond
    for power in [64u64, 1024, 1 << 20] {
        runner.bench(&format!("binary/N{power}"), || {
            black_box(Plan::binary(black_box(power), false));
        });
        runner.bench(&format!("binary-fused/N{power}"), || {
            black_box(Plan::binary(black_box(power), true));
        });
        runner.bench(&format!("chained/N{power}"), || {
            black_box(Plan::chained(black_box(power), &[4, 2]));
        });
        runner.bench(&format!("addition-chain/N{power}"), || {
            black_box(Plan::addition_chain(black_box(power)));
        });
    }

    // plan replay bookkeeping (modular scalars: pure schedule cost)
    let plan = Plan::binary(1 << 20, false);
    runner.bench("eval_mod/N2^20", || {
        black_box(plan.eval_mod(black_box(3), 1_000_003).unwrap());
    });

    // validation (runs in every engine call — must stay negligible)
    let big = Plan::addition_chain(4095);
    runner.bench("validate/addition-chain-4095", || {
        big.validate().unwrap();
    });

    // wire-protocol encode of a 512x512 matrix response (the serving
    // hot path for large matrices)
    let m512 = matexp::linalg::matrix::Matrix::random(512, 3);
    let resp = matexp::server::proto::WireResponse::Ok {
        result: Some(m512.data().to_vec()),
        stats: None,
        metrics: None,
        payload: matexp::server::proto::Payload::Json,
        id: None,
        frame: None,
    };
    runner.bench("wire-encode/512x512/json", || {
        black_box(resp.encode().unwrap());
    });
    let line = resp.encode().unwrap();
    runner.bench("wire-decode/512x512/json", || {
        black_box(matexp::server::proto::WireResponse::decode(black_box(&line)).unwrap());
    });
    let resp_b64 = matexp::server::proto::WireResponse::Ok {
        result: Some(m512.data().to_vec()),
        stats: None,
        metrics: None,
        payload: matexp::server::proto::Payload::Base64,
        id: None,
        frame: None,
    };
    runner.bench("wire-encode/512x512/b64", || {
        black_box(resp_b64.encode().unwrap());
    });
    let line_b64 = resp_b64.encode().unwrap();
    runner.bench("wire-decode/512x512/b64", || {
        black_box(matexp::server::proto::WireResponse::decode(black_box(&line_b64)).unwrap());
    });

    runner.report();

    // A4: CPU matmul variants (the "fair CPU" ablation)
    for n in [128usize, 256] {
        let arms = ablations::cpu_variants(n, 42);
        print!(
            "{}",
            report::render_ablation(&format!("A4 CPU matmul variants (n={n})"), &arms)
        );
        println!();
    }
}
