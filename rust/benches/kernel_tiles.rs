//! `cargo bench --bench kernel_tiles` — ablation A1 (paper §4.3.7):
//! kernel-level matmul comparison.
//!
//! Default build: the engine matmul launch on the configured backend
//! across sizes, next to the raw CPU matmul variants (ablation A4's
//! substrate, measured here per-launch).
//!
//! With `--features xla` + `make artifacts`: additionally sweeps the
//! tiled Pallas matmul artifacts across TILE/block sizes. Pallas
//! artifacts run in interpret mode on the CPU PJRT plugin, so those wall
//! numbers quantify *structure* (launch count, transfer discipline, block
//! bookkeeping), not TPU performance (DESIGN.md §3).

use matexp::bench::{BenchConfig, Runner};
use matexp::config::MatexpConfig;
use matexp::linalg::matrix::Matrix;
use matexp::runtime::AnyEngine;
use std::time::Duration;

fn main() {
    let cfg = MatexpConfig::default();
    let mut engine = AnyEngine::from_config(&cfg).expect("backend");

    #[cfg(feature = "xla")]
    tile_sweep(&cfg);

    // engine matmul launch at the paper's sizes, properly sampled
    let mut runner = Runner::with_config(
        "engine matmul launch",
        BenchConfig {
            warmup_iters: 1,
            min_samples: 5,
            max_samples: 20,
            time_budget: Duration::from_secs(10),
        },
    );
    for n in [64usize, 128, 256] {
        let a = Matrix::random_spectral(n, 0.99, cfg.seed);
        let b = Matrix::random_spectral(n, 0.99, cfg.seed ^ 1);
        runner.bench(&format!("matmul/engine/n{n}"), || {
            let (m, _) = engine.matmul(&a, &b).expect("matmul");
            matexp::bench::black_box(&m);
        });
    }
    runner.report();

    // raw CPU matmul variants (the substrate behind the cpu backend)
    for n in [128usize, 256] {
        let arms = matexp::experiments::ablations::cpu_variants(n, cfg.seed);
        print!(
            "{}",
            matexp::experiments::report::render_ablation(
                &format!("A4 CPU matmul variants (n={n})"),
                &arms
            )
        );
        println!();
    }
}

#[cfg(feature = "xla")]
fn tile_sweep(cfg: &MatexpConfig) {
    use matexp::experiments::{ablations, report};
    use matexp::runtime::artifacts::ArtifactRegistry;
    use matexp::runtime::Engine;

    let Ok(registry) = ArtifactRegistry::discover(&cfg.artifacts_dir) else {
        eprintln!("artifacts missing; skipping the PJRT tile sweep");
        return;
    };
    let mut engine = Engine::pjrt(&registry, cfg.variant).expect("pjrt engine");
    for n in [128usize, 256] {
        if registry.tiles("matmul", n).is_empty() {
            continue;
        }
        let arms =
            ablations::tile_sweep(&mut engine, &registry, n, cfg.seed).expect("tile sweep");
        print!("{}", report::render_ablation(&format!("A1 TILE sweep (n={n})"), &arms));
    }
}
