//! `cargo bench --bench kernel_tiles` — ablation A1 (paper §4.3.7):
//! the tiled Pallas matmul kernel across TILE/block sizes, plus the
//! untiled XLA variant as the reference point.
//!
//! Pallas artifacts run in interpret mode on the CPU PJRT plugin, so the
//! wall numbers quantify *structure* (launch count, transfer discipline,
//! block bookkeeping), not TPU performance; the manifest's VMEM/MXU
//! estimates printed alongside are the TPU-side story (DESIGN.md §3).

use matexp::bench::{BenchConfig, Runner};
use matexp::config::MatexpConfig;
use matexp::experiments::{ablations, report};
use matexp::linalg::matrix::Matrix;
use matexp::runtime::artifacts::ArtifactRegistry;
use matexp::runtime::engine::Engine;
use matexp::runtime::Variant;
use std::time::Duration;

fn main() {
    let cfg = MatexpConfig::default();
    let Ok(registry) = ArtifactRegistry::discover(&cfg.artifacts_dir) else {
        eprintln!("artifacts missing; run `make artifacts`");
        return;
    };
    let mut engine = Engine::new(&registry, Variant::Xla).expect("engine");

    // tile sweep at the sizes the manifest carries tiles for
    for n in [128usize, 256] {
        if registry.tiles("matmul", n).is_empty() {
            continue;
        }
        let arms = ablations::tile_sweep(&mut engine, &registry, n, cfg.seed)
            .expect("tile sweep");
        print!("{}", report::render_ablation(&format!("A1 TILE sweep (n={n})"), &arms));
    }

    // reference: the untiled xla matmul at the same sizes, properly sampled
    let mut runner = Runner::with_config(
        "untiled xla matmul reference",
        BenchConfig {
            warmup_iters: 1,
            min_samples: 5,
            max_samples: 20,
            time_budget: Duration::from_secs(10),
        },
    );
    for n in [128usize, 256, 512] {
        let a = Matrix::random_spectral(n, 0.99, cfg.seed);
        let b = Matrix::random_spectral(n, 0.99, cfg.seed ^ 1);
        runner.bench(&format!("matmul/xla/n{n}"), || {
            let (m, _) = engine.matmul(&a, &b).expect("matmul");
            matexp::bench::black_box(&m);
        });
    }
    runner.report();
}
