//! `cargo bench --bench transfer_ablation` — ablation A2 (paper §4.3.8,
//! "the data is offloaded only log(N) times"): the SAME binary plan under
//! two residency disciplines — device-resident registers vs a full host
//! round-trip per launch — across sizes, plus the fusion ablation A3.
//!
//! Runs on the config-selected backend (pure-Rust CPU by default).

use matexp::config::MatexpConfig;
use matexp::experiments::{ablations, report};
use matexp::runtime::AnyEngine;

fn main() {
    let cfg = MatexpConfig::default();
    let mut engine = AnyEngine::from_config(&cfg).expect("backend");

    for (n, power) in [(64usize, 256u64), (128, 256), (256, 64)] {
        let arms = ablations::transfer_ablation(&mut engine, n, power, cfg.seed)
            .expect("transfer ablation");
        print!(
            "{}",
            report::render_ablation(&format!("A2 transfers (n={n}, N={power})"), &arms)
        );
        let resident = arms[0].wall_s;
        let roundtrip = arms[1].wall_s;
        println!(
            "residency speedup at n={n}: {:.2}x (transfers {} -> {})\n",
            roundtrip / resident,
            arms[1].transfers,
            arms[0].transfers
        );
    }

    for (n, power) in [(64usize, 256u64), (128, 512)] {
        let arms = ablations::fusion_ablation(&mut engine, n, power, cfg.seed)
            .expect("fusion ablation");
        print!(
            "{}",
            report::render_ablation(&format!("A3 launch fusion (n={n}, N={power})"), &arms)
        );
        println!();
    }
}
