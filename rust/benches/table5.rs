//! `cargo bench --bench table5` — regenerates paper Table 5
//! (n=512) and its figures: paper vs simulated vs measured, with the
//! measured column produced on the config-selected backend (pure-Rust
//! CPU by default — no artifacts needed).

use matexp::bench::Runner;
use matexp::config::MatexpConfig;
use matexp::experiments::{report, run_table};
use matexp::runtime::AnyEngine;

fn main() {
    let mut cfg = MatexpConfig::default();
    // caps only the sequential-CPU arm (extrapolated from 4 multiplies);
    // the naive-GPU arm still performs its full power-1 multiply chain on
    // the configured backend, so the large-n tables take a while on the
    // default pure-Rust CpuBackend
    cfg.cpu_measure_cap = 4;
    let mut engine = AnyEngine::from_config(&cfg).expect("backend");
    let t = run_table(5, &cfg, Some(&mut engine)).expect("table 5");
    print!("{}", report::render_table(&t));
    print!("{}", report::render_figures(&t));

    // classic bench table over the measured cells
    let mut runner = Runner::new("table5 (n=512) measured cells");
    for c in &t.cells {
        if let Some(m) = c.measured {
            runner.record(&format!("n{}/N{}/naive-gpu", c.n, c.power), m.naive_gpu_s);
            runner.record(&format!("n{}/N{}/seq-cpu(extrap)", c.n, c.power), m.seq_cpu_s);
            runner.record(&format!("n{}/N{}/ours", c.n, c.power), m.ours_s);
        }
    }
    runner.report();
}
