//! `cargo bench --bench batcher` — serving-layer benches: pure batcher
//! admission throughput (no engine), then end-to-end service throughput
//! with real backend workers on small matrices.

use std::sync::Arc;
use std::time::{Duration, Instant};

use matexp::bench::{black_box, format_secs, BenchConfig, Runner};
use matexp::config::{BatcherConfig, MatexpConfig};
use matexp::coordinator::batcher::Batcher;
use matexp::coordinator::request::{ExpmRequest, Method};
use matexp::coordinator::service::Service;
use matexp::exec::Submission;
use matexp::linalg::matrix::Matrix;

fn main() {
    pure_batcher_throughput();
    service_throughput();
}

/// Batcher policy cost per request, no engine involved.
fn pure_batcher_throughput() {
    let mut runner = Runner::with_config(
        "batcher (pure, no engine)",
        BenchConfig {
            warmup_iters: 2,
            min_samples: 10,
            max_samples: 50,
            time_budget: Duration::from_secs(3),
        },
    );
    const REQS: usize = 10_000;
    for sizes in [1usize, 4] {
        let cfg = BatcherConfig { max_batch: 16, max_wait_ms: 1000, max_queue: usize::MAX };
        // consecutive tiny sizes: measures the batcher, not matrix clones
        let matrices: Vec<Matrix> = (0..sizes).map(|i| Matrix::zeros(8 + i)).collect();
        runner.bench(&format!("push10k/{sizes}sizes"), || {
            let mut b = Batcher::new(cfg.clone());
            let now = Instant::now();
            let mut shipped = 0usize;
            for i in 0..REQS {
                let req =
                    ExpmRequest::new(i as u64, matrices[i % sizes].clone(), 64, Method::Ours);
                if let Some(batch) = b.push(req, now) {
                    shipped += batch.requests.len();
                }
            }
            shipped += b.flush_all().iter().map(|x| x.requests.len()).sum::<usize>();
            assert_eq!(shipped, REQS);
            black_box(shipped);
        });
    }
    runner.report();
    println!(
        "note: 10k admissions per sample; divide the median by 10k for per-request cost\n"
    );
}

/// End-to-end service: mixed small-matrix workload through the full
/// collector → batcher → worker → reply path.
fn service_throughput() {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 4;
    cfg.batcher.max_wait_ms = 1;
    cfg.warmup_sizes = vec![16]; // workers start warm for the benched size
    let service = match Service::start(cfg) {
        Ok(s) => Arc::new(s),
        Err(e) => {
            eprintln!("service failed to start: {e}");
            return;
        }
    };
    // warm all worker engines (through the async submission surface)
    for _ in 0..8 {
        let a = Matrix::random_spectral(16, 0.9, 7);
        let mut job = service.submit_job(Submission::expm(a, 64)).expect("warm submit");
        job.wait().expect("warm");
    }

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 50;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let service = Arc::clone(&service);
            scope.spawn(move || {
                let a = Matrix::random_spectral(16, 0.9, c as u64);
                for i in 0..PER_CLIENT {
                    let power = [64u64, 128, 256][(c + i) % 3];
                    let mut job = service
                        .submit_job(Submission::expm(a.clone(), power))
                        .expect("submit");
                    let resp = job.wait().expect("serve");
                    black_box(resp.stats.launches);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let total = (CLIENTS * PER_CLIENT) as f64;
    let m = service.metrics();
    println!("== service end-to-end (n=16, {CLIENTS} clients x {PER_CLIENT} reqs) ==");
    println!("throughput: {:.0} req/s  wall {}", total / wall, format_secs(wall));
    println!(
        "latency: mean {} p50 {} p99 {}",
        format_secs(m.latency_mean_us as f64 / 1e6),
        format_secs(m.latency_p50_us as f64 / 1e6),
        format_secs(m.latency_p99_us as f64 / 1e6),
    );
    println!(
        "batching: {} batches for {} requests ({:.2} req/batch)",
        m.batches_total,
        m.batched_requests_total,
        m.batched_requests_total as f64 / m.batches_total.max(1) as f64
    );
}
