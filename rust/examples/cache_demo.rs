//! The multi-tier cache in action: cold vs warm serving, per-submission
//! bypass/refresh, and byte-budget LRU eviction.
//!
//! ```bash
//! cargo run --release --example cache_demo
//! ```

use matexp::cache::{stats, CacheControl, ResultCache, ResultKey};
use matexp::coordinator::request::Method;
use matexp::coordinator::worker::build_worker_engine;
use matexp::exec::{Executor, Submission};
use matexp::linalg::matrix::Matrix;
use matexp::prelude::MatexpConfig;
use std::time::Instant;

fn main() -> matexp::error::Result<()> {
    // --- result caching is opt-in: flip it on like `--cache-results` ---
    let mut cfg = MatexpConfig::default();
    cfg.cache.results = true;
    cfg.cache.budget_mb = 64;
    let mut engine = build_worker_engine(&cfg, None)?;

    let a = Matrix::random_spectral(192, 0.99, 7);
    let n = a.n();
    let power = 1024;

    // cold: plans built, kernels prepared, 10 squarings executed
    let t0 = Instant::now();
    let cold = engine.run(Submission::expm(a.clone(), power))?;
    let cold_s = t0.elapsed().as_secs_f64();
    println!(
        "cold  : {:>8.3} ms  ({} launches, {} multiplies)",
        cold_s * 1e3,
        cold.stats.launches,
        cold.stats.multiplies
    );

    // warm: the identical request is answered from the result cache —
    // zero launches, bit-identical answer
    let t0 = Instant::now();
    let warm = engine.run(Submission::expm(a.clone(), power))?;
    let warm_s = t0.elapsed().as_secs_f64();
    println!(
        "warm  : {:>8.3} ms  ({} launches) — {:.0}x faster, bit-identical: {}",
        warm_s * 1e3,
        warm.stats.launches,
        cold_s / warm_s.max(f64::MIN_POSITIVE),
        warm.result == cold.result
    );

    // bypass: measure the real execution even though a warm entry exists
    let bypass = engine.run(Submission::expm(a.clone(), power).cache(CacheControl::Bypass))?;
    println!("bypass: re-executed with {} launches (cache untouched)", bypass.stats.launches);

    // refresh: recompute and overwrite the entry (manual invalidation)
    let refresh = engine.run(Submission::expm(a.clone(), power).cache(CacheControl::Refresh))?;
    println!("refresh: re-executed with {} launches, entry overwritten", refresh.stats.launches);
    let served = engine.run(Submission::expm(a, power))?;
    println!(
        "        …and the refreshed entry serves again ({} launches)",
        served.stats.launches
    );

    // --- byte-budget LRU eviction, on a private cache instance ---
    // budget fits exactly two n=64 results (16 KiB each)
    let cache = ResultCache::new(2 * 64 * 64 * 4);
    let mats: Vec<Matrix> = (0..3).map(|s| Matrix::random(64, s)).collect();
    for m in &mats {
        cache.insert(ResultKey::for_parts(m, 8, Method::Ours, None), m, Method::Ours, None);
    }
    println!(
        "\neviction: inserted 3 x 16 KiB under a 32 KiB budget -> {} entries, {} bytes, {} evicted",
        cache.len(),
        cache.bytes(),
        cache.evictions()
    );
    let oldest = ResultKey::for_parts(&mats[0], 8, Method::Ours, None);
    println!("        oldest entry evicted: {}", cache.get(&oldest).is_none());

    // --- the process-wide counters the server's metrics endpoint ships ---
    let c = stats::snapshot();
    println!(
        "\ncounters: plan {}h/{}m  prepared {}h/{}m  result {}h/{}m ({} bytes held)",
        c.plan_hits, c.plan_misses, c.prepared_hits, c.prepared_misses, c.result_hits,
        c.result_misses, c.result_bytes
    );
    println!("\n(n={n}, N={power}; try `matexp serve --cache-results` for the served path)");
    Ok(())
}
