//! Multi-device pool demo: the same `A^N` on one device, a homogeneous
//! sim pool, and a heterogeneous cpu+sim pool — with the cost-model
//! splitter's choices and the per-device breakdown printed.
//!
//! ```bash
//! cargo run --release --example multi_device
//! ```
//!
//! Pure Rust + the calibrated C2050 timing model: no GPU needed.

use matexp::prelude::*;
use matexp::pool::ShardDecision;

fn pool_cfg(devices: Vec<PoolDeviceKind>) -> MatexpConfig {
    let mut cfg = MatexpConfig::default();
    cfg.backend = BackendKind::Pool;
    cfg.pool.devices = devices;
    cfg
}

fn show(stats: &matexp::runtime::ExecStats) {
    println!(
        "  total: {:>3} launches, {:>4} tile-multiplies, {} transfers, wall {}",
        stats.launches,
        stats.multiplies,
        stats.h2d_transfers + stats.d2h_transfers,
        matexp::bench::format_secs(stats.wall_s)
    );
    for d in &stats.per_device {
        println!(
            "    {:<7} {:>3} launches, {:>4} multiplies, busy {}",
            d.device,
            d.launches,
            d.multiplies,
            matexp::bench::format_secs(d.wall_s)
        );
    }
}

fn main() -> Result<()> {
    let n = 1024;
    let power = 512;
    let a = Matrix::random_spectral(n, 0.999, 42);
    let plan = Plan::binary(power, false);

    // 1. one simulated C2050 (the paper's whole testbed)
    let mut cfg = MatexpConfig::default();
    cfg.backend = BackendKind::Sim;
    let mut single = AnyEngine::from_config(&cfg)?;
    let resp = single.run(Submission::expm(a.clone(), power).plan(plan.clone()))?;
    let (want, single_stats) = (resp.result, resp.stats);
    println!("single sim device ({}):", single.platform());
    show(&single_stats);

    // 2. four simulated C2050s: the splitter tile-shards each multiply
    let cfg4 = pool_cfg(vec![PoolDeviceKind::Sim; 4]);
    let mut pool4 = PoolEngine::from_config(&cfg4)?;
    match pool4.pool().shard_decision(n) {
        ShardDecision::Shard(sp) => println!(
            "\n4x sim pool shards on a {g}x{g} grid (predicted {pred}/multiply):",
            g = sp.grid,
            pred = matexp::bench::format_secs(sp.predicted_step_s)
        ),
        ShardDecision::Single { .. } => println!("\n4x sim pool declined to shard:"),
    }
    // the IDENTICAL submission, now answered by four devices
    let resp = pool4.run(Submission::expm(a.clone(), power).plan(plan.clone()))?;
    let (got, pool_stats) = (resp.result, resp.stats);
    assert!(got.approx_eq(&want, 1e-3, 1e-3), "pool result diverged");
    show(&pool_stats);
    println!(
        "  sharded speedup vs single device: {:.2}x",
        single_stats.wall_s / pool_stats.wall_s
    );

    // 3. heterogeneous cpu+sim pool on a batch of small requests:
    //    request-parallel dispatch, cost-model queues, work stealing
    let small_n = 48;
    let cfg_h = pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Sim]);
    let hetero = PoolEngine::from_config(&cfg_h)?;
    let reqs: Vec<ExpmRequest> = (0..16)
        .map(|i| {
            ExpmRequest::new(i + 1, Matrix::random_spectral(small_n, 0.95, i + 1), 64, Method::Ours)
        })
        .collect();
    let oracles: Vec<Matrix> = (0..16)
        .map(|i| {
            let a = Matrix::random_spectral(small_n, 0.95, i + 1);
            matexp::linalg::expm::expm(&a, 64, CpuAlgo::Ikj).expect("oracle")
        })
        .collect();
    let mut replies = hetero.execute_batch(reqs);
    replies.sort_by_key(|(id, _)| *id);
    for (id, outcome) in &replies {
        let resp = outcome.as_ref().expect("request served");
        let want = &oracles[(*id - 1) as usize];
        assert!(
            resp.result.approx_eq(want, 1e-3, 1e-3),
            "request {id} diverged from the oracle by {}",
            resp.result.max_abs_diff(want)
        );
    }
    println!("\ncpu+sim pool served {}/16 small requests (n={small_n}):", replies.len());
    let metrics = hetero.pool().metrics();
    for d in &metrics.devices {
        println!(
            "    {:<7} jobs {:>2}, steals {:>2}, busy {}",
            d.name,
            d.jobs,
            d.steals,
            matexp::bench::format_secs(d.busy_s)
        );
    }
    println!("\nall results agree with the single-device oracle.");
    Ok(())
}
