//! End-to-end serving demo: start the full stack in-process (coordinator
//! + TCP server), drive it with concurrent clients over real sockets, and
//! report latency/throughput — the paper's "supercomputer at every desk"
//! as a deployable service.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use std::sync::Arc;
use std::time::Instant;

use matexp::bench::format_secs;
use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::error::Result;
use matexp::linalg::matrix::Matrix;
use matexp::server::client::MatexpClient;
use matexp::server::server::serve_background;
use matexp::util::json::Json;

const CLIENTS: usize = 6;
const REQS_PER_CLIENT: usize = 24;

fn main() -> Result<()> {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 4;
    cfg.batcher.max_wait_ms = 1;
    cfg.warmup_sizes = vec![32, 64]; // workers start at steady-state latency

    println!("starting coordinator ({} workers) + TCP server…", cfg.workers);
    let service = Arc::new(Service::start(cfg)?);
    let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 16)?;
    let addr = server.local_addr().to_string();
    println!("serving on {addr} (sizes {:?})\n", service.sizes());

    // mixed workload: sizes 32/64, powers 64..1024, mostly `ours`;
    // half the clients use the compact base64 payload encoding
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|cid| {
                let addr = addr.clone();
                scope.spawn(move || -> Vec<f64> {
                    let mut client = MatexpClient::connect(&addr).expect("connect");
                    if cid % 2 == 0 {
                        client = client.with_base64();
                    }
                    let mut lat = Vec::with_capacity(REQS_PER_CLIENT);
                    for i in 0..REQS_PER_CLIENT {
                        let n = if (cid + i) % 3 == 0 { 32 } else { 64 };
                        let power = [64u64, 128, 256, 512, 1024][(cid + i) % 5];
                        let method = if i % 8 == 7 { Method::OursPacked } else { Method::Ours };
                        // 0.85: the power-iteration radius estimate can be
                        // ~15% off, and anything over 1.087 overflows f32
                        // at N=1024
                        let a = Matrix::random_spectral(n, 0.85, (cid * 1000 + i) as u64 + 1);
                        let t = Instant::now();
                        let (result, stats) = client.expm(&a, power, method).expect("expm");
                        lat.push(t.elapsed().as_secs_f64());
                        assert!(result.is_finite());
                        assert!(stats.launches <= 14);
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = latencies.len();
    let pct = |q: f64| latencies[((total as f64 * q) as usize).min(total - 1)];
    println!("== workload: {CLIENTS} clients × {REQS_PER_CLIENT} requests (sizes 32/64, N∈64..1024) ==");
    println!("throughput : {:.1} req/s ({} requests in {})", total as f64 / wall, total, format_secs(wall));
    println!("latency    : p50 {}  p90 {}  p99 {}", format_secs(pct(0.50)), format_secs(pct(0.90)), format_secs(pct(0.99)));

    // pipelining: ONE connection, a burst of id-tagged requests in
    // flight at once, resolved in reverse submission order
    let mut pipelined = MatexpClient::connect(&addr)?;
    let burst: Vec<(Matrix, matexp::server::client::PendingExpm)> = (0..8u64)
        .map(|i| {
            let a = Matrix::random_spectral(32, 0.85, 9000 + i);
            let ticket = pipelined.submit(&a, 64 + i, Method::Ours).expect("submit");
            (a, ticket)
        })
        .collect();
    for (_, ticket) in burst.iter().rev() {
        let (result, _) = pipelined.wait(ticket).expect("pipelined wait");
        assert!(result.is_finite());
    }
    println!("\npipelined burst: 8 in-flight requests on one connection, all answered");

    // server-side view over the metrics endpoint
    let mut client = MatexpClient::connect(&addr)?;
    let m = client.metrics()?;
    let get = |k: &str| m.get(k).and_then(Json::as_u64).unwrap_or(0);
    println!("\n== server metrics ==");
    println!("responses  : {}", get("responses_total"));
    println!("batches    : {} ({:.2} req/batch)", get("batches_total"),
        get("batched_requests_total") as f64 / get("batches_total").max(1) as f64);
    println!("launches   : {} for {} multiplies", get("launches_total"), get("multiplies_total"));
    println!(
        "the log(N) effect: {} multiplies would have cost {}+ launches naively",
        get("multiplies_total"),
        get("multiplies_total")
    );
    Ok(())
}
