//! Quickstart: compute `A^512` for a 64×64 matrix three ways and compare.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs on the config-selected backend (pure-Rust CPU by default; no
//! artifacts needed).

use matexp::prelude::*;

fn main() -> Result<()> {
    let cfg = MatexpConfig::default();
    let mut engine = AnyEngine::from_config(&cfg)?;
    println!("platform: {}", engine.platform());

    // a well-conditioned random input (spectral radius ≈ 1 so high powers
    // neither explode nor vanish in f32)
    let n = 64;
    let power = 512;
    let a = Matrix::random_spectral(n, 0.999, 42);
    engine.warmup_exec(n)?; // first execution of each op pays XLA thunk init

    // 1. the paper's approach: binary plan, device-resident buffers —
    //    submitted through the one execution surface (exec::Executor)
    let resp = engine.run(Submission::expm(a.clone(), power).plan(Plan::binary(power, true)))?;
    let (ours, ours_stats) = (resp.result, resp.stats);
    println!(
        "\nours       : {:>3} launches, {:>3} multiplies, {} transfers, {}",
        ours_stats.launches,
        ours_stats.multiplies,
        ours_stats.h2d_transfers + ours_stats.d2h_transfers,
        matexp::bench::format_secs(ours_stats.wall_s)
    );

    // 2. the naive GPU baseline: one launch per multiply, round-trip each
    let resp = engine.run(Submission::expm(a.clone(), power).method(Method::NaiveGpu))?;
    let (naive, naive_stats) = (resp.result, resp.stats);
    println!(
        "naive-gpu  : {:>3} launches, {:>3} multiplies, {} transfers, {}",
        naive_stats.launches,
        naive_stats.multiplies,
        naive_stats.h2d_transfers + naive_stats.d2h_transfers,
        matexp::bench::format_secs(naive_stats.wall_s)
    );

    // 3. the sequential CPU baseline (§4.1)
    let t0 = std::time::Instant::now();
    let cpu = matexp::linalg::expm::expm_naive(&a, power, matexp::linalg::CpuAlgo::Naive)?;
    println!(
        "seq-cpu    : {:>3} launches, {:>3} multiplies,  0 transfers, {}",
        0,
        power - 1,
        matexp::bench::format_secs(t0.elapsed().as_secs_f64())
    );

    // all three agree
    assert!(ours.approx_eq(&naive, 1e-3, 1e-3), "ours vs naive-gpu diverged");
    assert!(ours.approx_eq(&cpu, 1e-2, 1e-2), "ours vs cpu diverged");
    println!(
        "\nresults agree (max |ours - cpu| = {:.3e}); speedup vs naive-gpu: {:.1}x",
        ours.max_abs_diff(&cpu),
        naive_stats.wall_s / ours_stats.wall_s
    );
    Ok(())
}
