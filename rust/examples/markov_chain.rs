//! Markov-chain steady state via matrix powers — one of the scientific
//! workloads the paper's introduction motivates (statistical applications).
//!
//! For a row-stochastic transition matrix `P`, the rows of `P^N` converge
//! to the stationary distribution π as `N → ∞`. Binary exponentiation
//! makes the converged power essentially free: `P^1024` costs 10 launches.
//!
//! ```bash
//! cargo run --release --example markov_chain
//! ```

use matexp::prelude::*;

fn main() -> Result<()> {
    let cfg = MatexpConfig::default();
    let mut engine = AnyEngine::from_config(&cfg)?;

    let n = 64;
    let p = Matrix::random_stochastic(n, 7);

    println!("transition matrix: {n}x{n} row-stochastic");
    println!("{:<8} {:>10} {:>12} {:>14}", "power", "launches", "row spread", "wall");

    // as the power doubles the rows collapse onto π; watch the spread
    let mut prev_rows: Option<Matrix> = None;
    for power in [2u64, 8, 64, 512, 1024] {
        let resp = engine.run(Submission::expm(p.clone(), power).plan(Plan::binary(power, true)))?;
        let (pk, stats) = (resp.result, resp.stats);

        // spread = max over columns of (max - min) across rows; 0 ⇒ all
        // rows identical ⇒ converged to the stationary distribution
        let mut spread = 0.0f32;
        for j in 0..n {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..n {
                let v = pk.get(i, j);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            spread = spread.max(hi - lo);
        }
        println!(
            "{:<8} {:>10} {:>12.3e} {:>14}",
            power,
            stats.launches,
            spread,
            matexp::bench::format_secs(stats.wall_s)
        );
        prev_rows = Some(pk);
    }

    let pk = prev_rows.expect("ran at least one power");
    // π is any row of the converged power; verify stationarity: π P = π
    let pi: Vec<f32> = pk.row(0).to_vec();
    let mut pi_p = vec![0.0f32; n];
    for (j, out) in pi_p.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (k, &pik) in pi.iter().enumerate() {
            acc += pik * p.get(k, j);
        }
        *out = acc;
    }
    let err: f32 = pi
        .iter()
        .zip(&pi_p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    let mass: f32 = pi.iter().sum();
    println!("\nstationary distribution: Σπ = {mass:.6}, ‖πP − π‖∞ = {err:.3e}");
    assert!((mass - 1.0).abs() < 1e-3, "probability mass preserved");
    assert!(err < 1e-4, "π is stationary");
    println!("markov chain converged — binary exponentiation gave it in ~10 launches");
    Ok(())
}
