//! Path counting in graphs via adjacency-matrix powers — the classic
//! combinatorial use of matrix exponentiation: `(A^k)[i][j]` counts the
//! walks of length `k` from `i` to `j`.
//!
//! Builds a 64-node ring with chords, counts walks with the configured
//! backend engine, and cross-checks exact counts against a CPU u64
//! dynamic program.
//!
//! ```bash
//! cargo run --release --example graph_paths
//! ```

use matexp::prelude::*;

const N: usize = 64;

/// Ring + two chord families: sparse enough that walk counts of useful
/// lengths stay well inside f32's 2^24 exact-integer range.
fn adjacency() -> Matrix {
    let mut a = Matrix::zeros(N);
    for i in 0..N {
        a.set(i, (i + 1) % N, 1.0);
        a.set((i + 1) % N, i, 1.0);
        if i % 8 == 0 {
            let j = (i + 11) % N;
            a.set(i, j, 1.0);
            a.set(j, i, 1.0);
        }
    }
    a
}

/// Exact walk counts by u64 matrix power on the CPU (the oracle).
fn exact_walks(a: &Matrix, k: u64) -> Vec<u64> {
    let n = a.n();
    let to_u = |m: &Matrix| -> Vec<u64> {
        m.data().iter().map(|&v| v.round() as u64).collect()
    };
    let mul = |x: &Vec<u64>, y: &Vec<u64>| -> Vec<u64> {
        let mut out = vec![0u64; n * n];
        for i in 0..n {
            for l in 0..n {
                let xv = x[i * n + l];
                if xv == 0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += xv * y[l * n + j];
                }
            }
        }
        out
    };
    let base = to_u(a);
    let mut acc = base.clone();
    for _ in 1..k {
        acc = mul(&acc, &base);
    }
    acc
}

fn main() -> Result<()> {
    let cfg = MatexpConfig::default();
    let mut engine = AnyEngine::from_config(&cfg)?;

    let a = adjacency();
    println!("graph: {N}-ring + chords, {} edges", a.data().iter().filter(|&&v| v > 0.0).count() / 2);
    println!("{:<8} {:>12} {:>10} {:>12} {:>10}", "length", "walks(0→0)", "launches", "max count", "exact?");

    for k in [2u64, 4, 8, 12] {
        let resp = engine.run(Submission::expm(a.clone(), k).plan(Plan::binary(k, true)))?;
        let (ak, stats) = (resp.result, resp.stats);
        let exact = exact_walks(&a, k);

        // every count must round-trip exactly through f32
        let mut all_exact = true;
        let mut max_count = 0u64;
        for (got, want) in ak.data().iter().zip(&exact) {
            if got.round() as u64 != *want {
                all_exact = false;
            }
            max_count = max_count.max(*want);
        }
        assert!(
            max_count < (1 << 24),
            "walk counts exceeded f32 exact-integer range"
        );
        assert!(all_exact, "k={k}: GPU counts diverged from exact u64 counts");
        println!(
            "{:<8} {:>12} {:>10} {:>12} {:>10}",
            k,
            ak.get(0, 0).round() as u64,
            stats.launches,
            max_count,
            "yes"
        );
    }

    // connectivity: diameter bound — some power with all entries > 0
    let a16 = engine.run(Submission::expm(a.clone(), 16).plan(Plan::binary(16, true)))?.result;
    let reachable = a16.data().iter().filter(|&&v| v > 0.0).count();
    println!(
        "\nafter 16 steps {reachable}/{} node pairs are connected by a walk",
        N * N
    );
    Ok(())
}
