//! Regenerate every table and figure of the paper's evaluation in one run.
//!
//! ```bash
//! cargo run --release --example paper_tables            # simulated only (fast)
//! cargo run --release --example paper_tables -- --measure  # + this testbed
//! ```

use matexp::config::MatexpConfig;
use matexp::error::Result;
use matexp::experiments::{report, run_table, run_table_sim};
use matexp::runtime::AnyEngine;
use matexp::simulator::device::DeviceSpec;

fn main() -> Result<()> {
    let measure = std::env::args().any(|a| a == "--measure");
    let cfg = MatexpConfig::default();

    // Table 1: the device specification, verbatim
    println!("== paper Table 1: device specification ==");
    for (k, v) in DeviceSpec::tesla_c2050().table1_rows() {
        println!("{k:<34} {v}");
    }
    println!();

    let mut engine: Option<AnyEngine> = if measure {
        Some(AnyEngine::from_config(&cfg)?)
    } else {
        None
    };

    for id in 2..=5u8 {
        let t = match engine.as_mut() {
            Some(e) => run_table(id, &cfg, Some(e))?,
            None => run_table_sim(id, &cfg)?,
        };
        print!("{}", report::render_table(&t));
        print!("{}", report::render_figures(&t));
        println!();
    }
    if !measure {
        println!("(simulated columns only — rerun with --measure for this-testbed numbers)");
    }
    Ok(())
}
